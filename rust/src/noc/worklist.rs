//! Dirty-router worklist: a flat bitset over router indices.
//!
//! The mesh keeps the exact set of routers with at least one queued flit in
//! one of these, so a cycle costs O(active routers) instead of O(dim²) —
//! the sparsity-exploiting scheduling move (sparse spike traffic leaves most
//! routers idle most cycles; see EXPERIMENTS.md §Perf). Word-wise iteration
//! visits indices in ascending order, which keeps the cross-router order of
//! `east_egress` identical to the naive row-major scan — a requirement for
//! bit-for-bit golden equivalence with the reference engine.

// worklist slot indices narrow deliberately within engine bounds
#![allow(clippy::cast_possible_truncation)]

/// A fixed-universe bitset with ascending-order iteration.
#[derive(Debug, Clone, Default)]
pub struct DirtySet {
    words: Vec<u64>,
}

impl DirtySet {
    /// A set over the universe `0..n`.
    pub fn new(n: usize) -> Self {
        DirtySet { words: vec![0; n.div_ceil(64)] }
    }

    #[inline]
    pub fn insert(&mut self, i: usize) {
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn contains(&self, i: usize) -> bool {
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    pub fn clear(&mut self) {
        self.words.fill(0);
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Visit every set index in ascending order.
    pub fn for_each(&self, mut f: impl FnMut(usize)) {
        for (wi, &word) in self.words.iter().enumerate() {
            let mut w = word;
            while w != 0 {
                f((wi << 6) | w.trailing_zeros() as usize);
                w &= w - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_contains_clear() {
        let mut s = DirtySet::new(200);
        assert!(s.is_empty());
        for i in [0, 63, 64, 65, 127, 128, 199] {
            s.insert(i);
            assert!(s.contains(i));
        }
        assert!(!s.contains(1));
        assert_eq!(s.count(), 7);
        s.clear();
        assert!(s.is_empty());
        assert!(!s.contains(63));
    }

    #[test]
    fn iterates_ascending() {
        let mut s = DirtySet::new(300);
        let want = [5usize, 17, 63, 64, 130, 255, 299];
        // insert out of order; iteration must still be ascending
        for &i in [130usize, 5, 299, 64, 17, 255, 63].iter() {
            s.insert(i);
        }
        let mut got = Vec::new();
        s.for_each(|i| got.push(i));
        assert_eq!(got, want);
    }

    #[test]
    fn double_insert_is_idempotent() {
        let mut s = DirtySet::new(64);
        s.insert(10);
        s.insert(10);
        assert_eq!(s.count(), 1);
        let mut got = Vec::new();
        s.for_each(|i| got.push(i));
        assert_eq!(got, vec![10]);
    }

    #[test]
    fn single_element_universe() {
        // dim-1 mesh edge: one router, one bit, one word
        let mut s = DirtySet::new(1);
        assert!(s.is_empty());
        s.insert(0);
        assert!(s.contains(0));
        assert_eq!(s.count(), 1);
        let mut got = Vec::new();
        s.for_each(|i| got.push(i));
        assert_eq!(got, vec![0]);
        s.clear();
        assert!(s.is_empty());
    }

    #[test]
    fn full_universe_iterates_every_index() {
        // saturating-mesh edge: every bit set, including a partial top word
        for n in [63usize, 64, 65, 200, 256] {
            let mut s = DirtySet::new(n);
            for i in 0..n {
                s.insert(i);
            }
            assert_eq!(s.count(), n, "n={n}");
            let mut got = Vec::new();
            s.for_each(|i| got.push(i));
            assert_eq!(got, (0..n).collect::<Vec<_>>(), "n={n}");
        }
    }

    #[test]
    fn reinsert_after_clear_and_after_visit() {
        // the mesh re-dirties routers that keep backlog across cycles: the
        // same index must be insertable again after clear with no residue
        let mut s = DirtySet::new(128);
        s.insert(77);
        s.clear();
        assert!(!s.contains(77));
        s.insert(77);
        s.insert(3);
        let mut got = Vec::new();
        s.for_each(|i| got.push(i));
        assert_eq!(got, vec![3, 77]);
    }
}
