//! Traffic generation: turn a layer edge's packet counts into concrete
//! (src, dest) injections for the cycle-level simulators. The event sets
//! are owned by the boundary codecs ([`crate::codec`]): dense edges emit
//! one packet per activation slot, rate-coded edges Bernoulli-sample events
//! at the layer's firing activity over T ticks (Eq. 2), and the temporal /
//! top-k-delta codecs filter that same fire pattern (TTFS first-fires,
//! rising edges). This module keeps the legacy two-mode entry point and the
//! analytic convergence check.

// neuron indices and tick counters narrow deliberately within edge bounds
#![allow(clippy::cast_possible_truncation)]

use crate::codec::{BoundaryCodec, CodecId, DenseCodec, RateCodec};

use super::duplex::CrossTraffic;

/// Generate cross-die traffic for one boundary edge (legacy two-mode
/// surface, kept for the pre-codec callers and the scenario back-compat
/// rule: `dense > 0` selects [`DenseCodec`], otherwise [`RateCodec`]).
///
/// * `neurons` — source-layer neuron count mapped on the boundary cores;
/// * `dense_packets_per_neuron` — ceil(bits/8) for dense, 0 for spiking;
/// * `activity`, `ticks` — spiking parameters (used when dense == 0);
/// * neuron i sources from boundary row `i % dim` (the paper's 8 peripheral
///   ports) and targets the mirrored tile on the far chip
///   ([`crate::codec::edge_endpoints`]).
pub fn boundary_edge_traffic(
    neurons: usize,
    dense_packets_per_neuron: usize,
    activity: f64,
    ticks: u32,
    dim: usize,
    seed: u64,
) -> Vec<CrossTraffic> {
    if dense_packets_per_neuron > 0 {
        // DenseCodec derives packets-per-neuron as ceil(bits/8)
        let bits = dense_packets_per_neuron as u32 * 8;
        DenseCodec.edge_traffic(neurons, activity, ticks, bits, dim, seed)
    } else {
        RateCodec.edge_traffic(neurons, activity, ticks, 8, dim, seed)
    }
}

/// Generate one boundary edge's traffic through an arbitrary codec handle
/// — the codec-aware successor of [`boundary_edge_traffic`], used by
/// [`super::scenario::TrafficSpec::Boundary`].
pub fn codec_edge_traffic(
    codec: CodecId,
    neurons: usize,
    activity: f64,
    ticks: u32,
    bits: u32,
    dim: usize,
    seed: u64,
) -> Vec<CrossTraffic> {
    codec.codec().edge_traffic(neurons, activity, ticks, bits, dim, seed)
}

/// Expected packet count for a spiking edge (the analytic model's number) —
/// used to check the sampled traffic converges to it.
pub fn expected_spike_packets(neurons: usize, activity: f64, ticks: u32) -> f64 {
    neurons as f64 * activity * ticks as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_edge_exact_count() {
        let t = boundary_edge_traffic(256, 1, 0.0, 0, 8, 1);
        assert_eq!(t.len(), 256);
        let t32 = boundary_edge_traffic(256, 4, 0.0, 0, 8, 1);
        assert_eq!(t32.len(), 1024); // 32-bit -> 4 packets per neuron
    }

    #[test]
    fn spike_edge_statistical_count() {
        let t = boundary_edge_traffic(4096, 0, 0.1, 8, 8, 42);
        let expect = expected_spike_packets(4096, 0.1, 8);
        let got = t.len() as f64;
        assert!((got - expect).abs() / expect < 0.10, "got={got} expect={expect}");
    }

    #[test]
    fn srcs_are_boundary_cores() {
        let t = boundary_edge_traffic(64, 1, 0.0, 0, 8, 3);
        assert!(t.iter().all(|c| c.src.x == 7));
    }

    #[test]
    fn zero_activity_no_packets() {
        let t = boundary_edge_traffic(1024, 0, 0.0, 8, 8, 5);
        assert!(t.is_empty());
    }

    #[test]
    fn full_activity_fires_every_tick() {
        // activity 1.0 is the dense limit of rate coding: every one of the
        // N x T Bernoulli draws fires, exactly.
        let t = boundary_edge_traffic(16, 0, 1.0, 8, 8, 3);
        assert_eq!(t.len(), 16 * 8);
    }

    #[test]
    fn edge_distribution_hand_checked() {
        // neuron i sources from the East boundary column at row i % dim and
        // targets column (i / dim) % dim of the mirrored row on the far chip
        let dim = 4;
        let t = boundary_edge_traffic(10, 1, 0.0, 0, dim, 9);
        assert_eq!(t.len(), 10);
        for (i, tr) in t.iter().enumerate() {
            assert_eq!(tr.src.x as usize, dim - 1, "neuron {i}");
            assert_eq!(tr.src.y as usize, i % dim, "neuron {i}");
            assert_eq!(tr.dest.x as usize, (i / dim) % dim, "neuron {i}");
            assert_eq!(tr.dest.y as usize, i % dim, "neuron {i}");
        }
        // hand-computed spots: neuron 5 -> row 1, dest column 1;
        // neuron 9 -> row 1, dest column 2
        assert_eq!((t[5].src.y, t[5].dest.x), (1, 1));
        assert_eq!((t[9].src.y, t[9].dest.x), (1, 2));
        // rows cycle through the dim boundary ports uniformly
        for row in 0..dim {
            let on_row = t.iter().filter(|c| c.src.y as usize == row).count();
            assert!(on_row >= 2, "row {row} underused: {on_row}");
        }
    }

    #[test]
    fn expected_spike_packets_hand_computed() {
        // N x activity x T, against hand-worked values
        assert!((expected_spike_packets(256, 0.1, 8) - 204.8).abs() < 1e-9);
        assert_eq!(expected_spike_packets(4096, 0.5, 4), 8192.0);
        assert_eq!(expected_spike_packets(100, 0.0, 8), 0.0); // silent edge
        assert_eq!(expected_spike_packets(100, 1.0, 8), 800.0); // dense limit
        assert_eq!(expected_spike_packets(0, 0.7, 8), 0.0);

        // the sampled trace converges on the closed form at both boundaries
        let silent = boundary_edge_traffic(512, 0, 0.0, 8, 8, 1);
        assert_eq!(silent.len() as f64, expected_spike_packets(512, 0.0, 8));
        let dense = boundary_edge_traffic(512, 0, 1.0, 8, 8, 1);
        assert_eq!(dense.len() as f64, expected_spike_packets(512, 1.0, 8));
    }

    #[test]
    fn deterministic_in_seed() {
        let a = boundary_edge_traffic(100, 0, 0.3, 8, 8, 11);
        let b = boundary_edge_traffic(100, 0, 0.3, 8, 8, 11);
        assert_eq!(a.len(), b.len());
    }

    #[test]
    fn codec_path_reproduces_legacy_two_mode_traffic() {
        // the legacy entry point and the codec-aware one must agree event
        // for event on the two pre-codec encodings (same RNG draw order)
        for seed in [1u64, 9, 77] {
            let legacy_rate = boundary_edge_traffic(200, 0, 0.25, 8, 8, seed);
            let codec_rate = codec_edge_traffic(CodecId::Rate, 200, 0.25, 8, 8, 8, seed);
            assert_eq!(legacy_rate, codec_rate, "seed {seed}");
            let legacy_dense = boundary_edge_traffic(200, 4, 0.0, 0, 8, seed);
            let codec_dense = codec_edge_traffic(CodecId::Dense, 200, 0.0, 0, 32, 8, seed);
            assert_eq!(legacy_dense, codec_dense, "seed {seed}");
        }
    }

    #[test]
    fn new_codecs_thin_the_rate_event_set() {
        let n = 512;
        let rate = codec_edge_traffic(CodecId::Rate, n, 0.2, 8, 8, 8, 5);
        let topk = codec_edge_traffic(CodecId::TopKDelta, n, 0.2, 8, 8, 8, 5);
        let ttfs = codec_edge_traffic(CodecId::Temporal, n, 0.2, 8, 8, 8, 5);
        assert!(rate.len() >= topk.len() && topk.len() >= ttfs.len());
        assert!(ttfs.len() <= n, "TTFS emits at most one event per neuron");
        assert!(!ttfs.is_empty(), "activity 0.2 over 8 ticks must fire");
    }
}
