//! Cycle-level NoC simulator (§4.2's "custom simulation framework", the
//! clocked counterpart of the closed-form `analytic` engine).
//!
//! * [`router`] — 5-port X-Y routers with East/West priority;
//! * [`mesh`]   — a synchronous N x N mesh of routers (one chip);
//! * [`emio`]   — the §3.4 merge/SerDes/split die-to-die block
//!   (validates the 76-cycle single-packet RTL figure);
//! * [`duplex`] — two chips + one EMIO link, end-to-end;
//! * [`traffic`] — packet-trace generation from layer workloads;
//! * [`clp`]    — the cross-layer packet converter state machine (Eqs. 2-3,
//!   integer-exact against the Pallas kernels).

pub mod chain;
pub mod clp;
pub mod core_sim;
pub mod model_sim;
pub mod duplex;
pub mod emio;
pub mod mesh;
pub mod router;
pub mod traffic;

pub use chain::{Chain, ChainTraffic};
pub use duplex::{CrossTraffic, Duplex};
pub use emio::EmioLink;
pub use mesh::{Mesh, MeshStats};
pub use router::{route_xy, Flit, Port, Router};
