//! Cycle-level NoC simulator (§4.2's "custom simulation framework", the
//! clocked counterpart of the closed-form `analytic` engine).
//!
//! * [`router`] — 5-port X-Y routers with East/West priority, ring-buffer
//!   input FIFOs of packed `Copy` flits;
//! * [`fifo`]   — the fixed-capacity ring buffer behind every input port;
//! * [`worklist`] — the dirty-router bitset that makes a mesh cycle cost
//!   O(active routers) instead of O(dim²);
//! * [`mesh`]   — a synchronous N x N mesh of routers (one chip) with
//!   worklist scheduling and an O(1) backlog counter;
//! * [`emio`]   — the §3.4 merge/SerDes/split die-to-die block
//!   (validates the 76-cycle single-packet RTL figure);
//! * [`duplex`] — two chips + one EMIO link, end-to-end;
//! * [`chain`]  — C chips in a directional-X chain with repeater hops;
//! * [`reference`] — the retained naive engine (full-scan, `VecDeque`
//!   FIFOs): golden-equivalence oracle and perf baseline;
//! * [`telemetry`] — zero-overhead-when-off per-packet delivery records
//!   ([`telemetry::NoopSink`] monomorphizes to nothing;
//!   [`telemetry::DeliverySink`] feeds the p50/p99/p999 figures);
//! * [`traffic`] — packet-trace generation from layer workloads;
//! * [`clp`]    — the cross-layer packet converter state machine (Eqs. 2-3,
//!   integer-exact against the Pallas kernels).

pub mod chain;
pub mod clp;
pub mod core_sim;
pub mod duplex;
pub mod emio;
pub mod fifo;
pub mod mesh;
pub mod model_sim;
pub mod reference;
pub mod router;
pub mod telemetry;
pub mod traffic;
pub mod worklist;

pub use chain::{Chain, ChainStats, ChainTraffic};
pub use duplex::{CrossTraffic, Duplex, DuplexStats};
pub use emio::EmioLink;
pub use mesh::{Mesh, MeshStats};
pub use reference::{RefChain, RefDuplex, RefMesh};
pub use router::{route_xy, Flit, Port, Router};
pub use telemetry::{Delivery, DeliverySink, NoopSink, TelemetrySink};
