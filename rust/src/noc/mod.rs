//! Cycle-level NoC simulator (§4.2's "custom simulation framework", the
//! clocked counterpart of the closed-form `analytic` engine).
//!
//! ## The unified engine surface
//!
//! Every clocked topology implements the [`engine::CycleEngine`] trait
//! (`now` / `inject` / `step` / `backlog` / `run_until_drained` / `stats` /
//! `deliveries` / `latency_hist`), returning one [`engine::NocStats`]
//! aggregate regardless of topology. [`scenario::Scenario`] builds any of
//! the six engines from a serializable description
//! (`Scenario::mesh(16)`, `Scenario::chain(4, 8)`, `.with_telemetry()`,
//! `.traffic(...)`, `.build()` / `.build_reference()`; JSON schema
//! `scenario/v1` in EXPERIMENTS.md §Perf), and [`harness`] holds the only
//! drivers in the repo: the golden/fuzz `lockstep` differential harness and
//! the `run_schedule` player behind the bench sweep and `spikelink noc-sim`.
//!
//! **Migration note** (pre-trait API): the per-topology constructors are
//! unchanged (`Mesh::new(dim)`, `Duplex::new(dim)`, `Chain::new(chips,
//! dim)`, `with_sink`/`with_sinks` for telemetry), but the per-topology
//! stats structs are gone — `MeshStats` is now an alias of
//! [`engine::NocStats`], and the old `DuplexStats`/`ChainStats` shapes
//! survive only as `From<NocStats>` shims in [`engine`]. `Duplex::run` /
//! `Chain::run` return [`engine::NocStats`]; per-topology driver loops
//! should be replaced with [`harness::run_schedule`] /
//! [`harness::lockstep`] over `CycleEngine`.
//!
//! ## Modules
//!
//! * [`engine`] — the [`engine::CycleEngine`] trait, [`engine::NocStats`],
//!   [`engine::Transfer`], and the legacy-stats migration shims;
//! * [`harness`] — generic lockstep + schedule drivers;
//! * [`scenario`] — serializable, reproducible scenario builder;
//! * [`router`] — 5-port X-Y routers with East/West priority, ring-buffer
//!   input FIFOs of packed `Copy` flits;
//! * [`fifo`]   — the fixed-capacity ring buffer behind every input port;
//! * [`worklist`] — the dirty-router bitset that makes a mesh cycle cost
//!   O(active routers) instead of O(dim²);
//! * [`mesh`]   — a synchronous N x N mesh of routers (one chip) with
//!   worklist scheduling and an O(1) backlog counter;
//! * [`soa`]    — the same mesh with struct-of-arrays scheduling state
//!   (flat credit/backlog/dirty arrays, vectorizable credit reset),
//!   bit-identical to [`mesh`];
//! * [`parallel`] — the multi-threaded chain stepper (one worker per chip
//!   block, barrier per cycle, double-buffered EMIO mailboxes),
//!   bit-identical to [`chain`] at any thread count;
//! * [`emio`]   — the §3.4 merge/SerDes/split die-to-die block
//!   (validates the 76-cycle single-packet RTL figure);
//! * [`faults`] — seeded fault plans (link-down windows, bit-error rates,
//!   stall windows, hot-spot bursts) with bounded-retry/credit-recovery
//!   semantics, threaded through both engine families in lockstep;
//! * [`duplex`] — two chips + one EMIO link, end-to-end;
//! * [`chain`]  — C chips in a directional-X chain with repeater hops;
//! * [`reference`] — the retained naive engines (full-scan, `VecDeque`
//!   FIFOs): golden-equivalence oracles and perf baselines;
//! * [`telemetry`] — zero-overhead-when-off per-packet delivery records
//!   ([`telemetry::NoopSink`] monomorphizes to nothing;
//!   [`telemetry::DeliverySink`] feeds the p50/p99/p999 figures);
//! * [`traffic`] — packet-trace generation from layer workloads, delegated
//!   to the boundary codecs ([`crate::codec`]); scenario `Boundary` traffic
//!   carries a [`crate::codec::CodecId`] (JSON `codec` field, optional and
//!   backward compatible);
//! * [`clp`]    — the cross-layer packet converter state machine (Eqs. 2-3,
//!   integer-exact against the Pallas kernels).

pub mod chain;
pub mod clp;
pub mod core_sim;
pub mod duplex;
pub mod emio;
pub mod engine;
pub mod faults;
pub mod fifo;
pub mod harness;
pub mod mesh;
pub mod model_sim;
pub mod parallel;
pub mod reference;
pub mod router;
pub mod scenario;
pub mod soa;
pub mod telemetry;
pub mod traffic;
pub mod worklist;

pub use chain::{Chain, ChainTraffic};
pub use duplex::{CrossTraffic, Duplex};
pub use emio::EmioLink;
pub use engine::{
    ChainStats, CycleEngine, DrainOutcome, DuplexStats, MeshStats, NocStats, Transfer,
};
pub use faults::{FaultEvent, FaultKind, FaultOp, FaultPlan, FaultSink, FaultStats};
pub use harness::{lockstep, run_schedule, Op};
pub use mesh::Mesh;
pub use parallel::ParallelChain;
pub use reference::{RefChain, RefDuplex, RefMesh};
pub use router::{route_xy, Flit, Port, Router};
pub use scenario::{Scenario, ScenarioResult, Topology, TrafficSpec};
pub use soa::SoaMesh;
pub use telemetry::{Delivery, DeliverySink, NoopSink, TelemetrySink};
