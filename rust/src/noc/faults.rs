//! Seeded, deterministic fault injection across the die-to-die fabric.
//!
//! A [`FaultPlan`] describes every fault a run suffers — link-down windows
//! on EMIO edges, per-edge flit bit-error rates, per-edge spike-timing
//! jitter, router stall windows, and hot-spot traffic bursts — from one
//! seed, so a faulted run is exactly as replayable as a clean one. The plan expands to [`FaultOp`]s
//! ([`FaultPlan::ops`]) that [`super::engine::CycleEngine::inject_fault`]
//! routes into the engines; the per-edge fault state itself lives inside
//! [`super::emio::EmioLink`] ([`LinkFaults`]), which both engine families
//! share, so the optimized and reference engines stay in lockstep under
//! identical plans by construction. Only router stalls need dual
//! implementations (`Mesh` vs `RefMesh`) — both count a stall cycle for
//! exactly the routers with a non-empty backlog.
//!
//! **Retry/timeout semantics** (the graceful-degradation guarantee): a
//! corrupted frame is re-sent through the merge FIFO up to `max_retries`
//! times — faults cost latency, not packets — unless `drop_corrupted` is
//! set (the spiking-codec interpretation: a corrupted event is worthless
//! and discarded). After a link-down window the pad stays blocked for
//! [`CREDIT_RECOVERY_CYCLES`] while flow-control credits re-establish.
//! Bounded retries keep every faulted run drainable; a *permanent* outage
//! is the one case that cannot drain, which the
//! [`super::engine::DrainOutcome`] cap reports instead of hanging.
//!
//! An all-zero plan ([`FaultPlan::is_zero`]) injects nothing and consumes
//! no RNG draws, so clean runs stay bit-identical to pre-fault behaviour.
//! Schema (`faults` block of scenario/v1) and the degradation-sweep
//! methodology are documented in EXPERIMENTS.md §Faults.

// seed mixing and fault-window arithmetic narrow deliberately
#![allow(clippy::cast_possible_truncation)]

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Pad cycles lost after an outage window ends, while link-level
/// flow-control credits re-establish.
pub const CREDIT_RECOVERY_CYCLES: u64 = 4;

/// Default bounded re-send budget per corrupted frame.
pub const DEFAULT_MAX_RETRIES: u32 = 3;

/// Largest accepted spike-timing jitter bound — a displacement wider than
/// this is a broken plan, not timing noise.
pub const MAX_JITTER_CYCLES: u64 = 1_000_000;

/// Derive the per-edge corruption RNG seed from a plan seed. Both engine
/// families call this same helper, so their draw streams are identical.
pub fn link_rng_seed(seed: u64, edge: usize) -> u64 {
    seed ^ (edge as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Salt separating the jitter draw stream from the corruption stream, so
/// enabling jitter on a link never perturbs which frames a given `ber`
/// corrupts (and vice versa).
const JITTER_SEED_SALT: u64 = 0xA5B3_57D1_9E02_C64F;

/// Derive the per-edge spike-timing-jitter RNG seed from a plan seed.
/// Shared by both engine families, like [`link_rng_seed`].
pub fn jitter_rng_seed(seed: u64, edge: usize) -> u64 {
    link_rng_seed(seed, edge) ^ JITTER_SEED_SALT
}

// ---------------------------------------------------------------------------
// counters, events, sink
// ---------------------------------------------------------------------------

/// Aggregate fault counters, carried inside
/// [`super::engine::NocStats::faults`] and compared per-op by the lockstep
/// harness. `corrupted == retried + dropped` (every corruption is resolved
/// one way or the other).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Frames whose payload a bit error corrupted at the pad.
    pub corrupted: u64,
    /// Corrupted frames re-sent through the merge FIFO.
    pub retried: u64,
    /// Corrupted frames discarded (`drop_corrupted` or retry budget spent).
    pub dropped: u64,
    /// Pad cycles lost to link-down windows (credit recovery included).
    pub link_down_cycles: u64,
    /// Router-cycles lost to stall windows (backlogged routers only).
    pub stall_cycles: u64,
    /// Frames whose deserializer-exit cycle timing jitter displaced
    /// (non-zero draws only — a TTFS decode error, a latency wobble for
    /// value-coded codecs).
    pub jittered: u64,
}

impl FaultStats {
    /// Fold another counter set into this one (topology aggregation).
    pub fn absorb(&mut self, o: &FaultStats) {
        self.corrupted += o.corrupted;
        self.retried += o.retried;
        self.dropped += o.dropped;
        self.link_down_cycles += o.link_down_cycles;
        self.stall_cycles += o.stall_cycles;
        self.jittered += o.jittered;
    }

    /// True when no fault was ever observed.
    pub fn is_zero(&self) -> bool {
        *self == FaultStats::default()
    }
}

/// How one corruption incident was resolved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Re-sent through the merge FIFO (costs queueing + another pad cycle).
    Retried,
    /// Discarded — the packet will never arrive.
    Dropped,
}

/// One per-frame fault incident, for the telemetry view.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub cycle: u64,
    /// Die boundary index (0 = the link leaving chip 0).
    pub edge: usize,
    /// The corrupted frame's packet id.
    pub id: u64,
    pub kind: FaultKind,
}

/// Merged fault telemetry of one engine: counters plus the per-incident
/// event log, ordered by `(cycle, edge, id)`. Asserted equal across engine
/// families after every lockstep op.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultSink {
    pub stats: FaultStats,
    pub events: Vec<FaultEvent>,
}

impl FaultSink {
    /// Canonical event order shared by both engine families.
    pub fn finish(mut self) -> FaultSink {
        self.events.sort_by_key(|e| (e.cycle, e.edge, e.id));
        self
    }
}

// ---------------------------------------------------------------------------
// fault ops — the engine-facing vocabulary
// ---------------------------------------------------------------------------

/// One fault directive, applied identically to both engines of a lockstep
/// pair via [`super::engine::CycleEngine::inject_fault`]. A [`FaultPlan`]
/// expands to these ([`FaultPlan::ops`]); the fuzz harness also generates
/// them directly. `Policy` must precede the link ops it parameterizes —
/// `ops()` guarantees the order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultOp {
    /// Seed the per-edge corruption RNGs and set the retry policy.
    Policy { seed: u64, max_retries: u32, drop_corrupted: bool },
    /// Per-frame corruption probability on one EMIO edge.
    BitError { edge: usize, rate: f64 },
    /// The pad of `edge` transmits nothing in `[from, until)` (plus
    /// [`CREDIT_RECOVERY_CYCLES`] of credit recovery afterwards).
    LinkDown { edge: usize, from: u64, until: u64 },
    /// Seeded spike-timing jitter on one EMIO edge: every clean frame's
    /// deserializer exit is displaced by a uniform draw in `[-max, +max]`
    /// cycles (clamped so a frame never exits before the cycle after it
    /// crossed the pad).
    Jitter { edge: usize, max: u64 },
    /// Routers on `chip` (all of them, or just `router` as a row-major
    /// index) skip arbitration while the clock is in `[from, until)`.
    Stall { chip: usize, router: Option<usize>, from: u64, until: u64 },
}

// ---------------------------------------------------------------------------
// per-link fault state (lives inside EmioLink, shared by both families)
// ---------------------------------------------------------------------------

/// Resolution of one frame offered to the pad (see
/// [`LinkFaults::pad_crossing`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PadVerdict {
    /// Uncorrupted: enter the deserializer pipeline.
    Clean,
    /// Corrupted, retry budget left: re-queue in the merge FIFO.
    Retry,
    /// Corrupted, dropped: the frame vanishes.
    Drop,
}

/// Fault state of one [`super::emio::EmioLink`]. `None` on a clean link —
/// the fault-free fast path is untouched and bit-identical.
#[derive(Debug, Clone)]
pub struct LinkFaults {
    rng: Rng,
    ber: f64,
    max_retries: u32,
    drop_corrupted: bool,
    edge: usize,
    /// `[from, until)` outage windows (absolute cycles).
    outages: Vec<(u64, u64)>,
    /// Spike-timing jitter bound (cycles); zero disables the draw stream.
    jitter_max: u64,
    /// Separate draw stream for jitter ([`jitter_rng_seed`]) so jitter and
    /// corruption never perturb each other's replay.
    jitter_rng: Rng,
    pub stats: FaultStats,
    pub events: Vec<FaultEvent>,
}

impl LinkFaults {
    /// Fault state for die boundary `edge` under plan seed `seed`.
    pub fn new(edge: usize, seed: u64) -> Self {
        LinkFaults {
            rng: Rng::new(link_rng_seed(seed, edge)),
            ber: 0.0,
            max_retries: DEFAULT_MAX_RETRIES,
            drop_corrupted: false,
            edge,
            outages: Vec::new(),
            jitter_max: 0,
            jitter_rng: Rng::new(jitter_rng_seed(seed, edge)),
            stats: FaultStats::default(),
            events: Vec::new(),
        }
    }

    /// Re-seed the corruption + jitter RNGs and set the retry policy (the
    /// [`FaultOp::Policy`] handler).
    pub fn set_policy(&mut self, seed: u64, max_retries: u32, drop_corrupted: bool) {
        self.rng = Rng::new(link_rng_seed(seed, self.edge));
        self.jitter_rng = Rng::new(jitter_rng_seed(seed, self.edge));
        self.max_retries = max_retries;
        self.drop_corrupted = drop_corrupted;
    }

    /// Set the per-frame corruption probability.
    pub fn set_ber(&mut self, rate: f64) {
        self.ber = rate;
    }

    /// Set the spike-timing jitter bound (the [`FaultOp::Jitter`] handler).
    pub fn set_jitter(&mut self, max: u64) {
        self.jitter_max = max;
    }

    /// Add an outage window `[from, until)`.
    pub fn add_outage(&mut self, from: u64, until: u64) {
        self.outages.push((from, until));
    }

    /// Pad blocked at `now` — inside an outage window or its credit
    /// recovery tail.
    pub fn pad_blocked(&self, now: u64) -> bool {
        self.outages
            .iter()
            .any(|&(from, until)| from <= now && now < until.saturating_add(CREDIT_RECOVERY_CYCLES))
    }

    /// Account one blocked pad cycle.
    pub fn note_blocked_cycle(&mut self) {
        self.stats.link_down_cycles += 1;
    }

    /// Decide the fate of a frame crossing the pad at `now`. The RNG is
    /// only consulted when `ber > 0`, so a zero-rate plan consumes no
    /// draws (bit-identity with clean runs).
    pub fn pad_crossing(&mut self, now: u64, id: u64, retries: u32) -> PadVerdict {
        if self.ber <= 0.0 || !self.rng.chance(self.ber) {
            return PadVerdict::Clean;
        }
        self.stats.corrupted += 1;
        if self.drop_corrupted || retries >= self.max_retries {
            self.stats.dropped += 1;
            self.events.push(FaultEvent { cycle: now, edge: self.edge, id, kind: FaultKind::Dropped });
            PadVerdict::Drop
        } else {
            self.stats.retried += 1;
            self.events.push(FaultEvent { cycle: now, edge: self.edge, id, kind: FaultKind::Retried });
            PadVerdict::Retry
        }
    }

    /// Deserializer-exit cycle of a clean frame that crossed the pad at
    /// `now` with nominal exit `base` (`now + DES_CYCLES`): displaced by a
    /// uniform draw in `[-jitter_max, +jitter_max]`, clamped so the frame
    /// never exits before `now + 1`. The jitter RNG is only consulted when
    /// the bound is non-zero, so a jitter-free plan consumes no draws
    /// (bit-identity with clean runs), and only non-zero displacements
    /// count as `jittered` — the TTFS decode-error numerator.
    pub fn jittered_exit(&mut self, now: u64, base: u64) -> u64 {
        if self.jitter_max == 0 {
            return base;
        }
        let draw = self.jitter_rng.below(2 * self.jitter_max + 1);
        if draw != self.jitter_max {
            self.stats.jittered += 1;
        }
        // base + (draw - jitter_max), computed without underflow
        (base + draw).saturating_sub(self.jitter_max).max(now + 1)
    }
}

// ---------------------------------------------------------------------------
// the plan
// ---------------------------------------------------------------------------

/// One link-down window in a plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkDown {
    pub edge: usize,
    pub from: u64,
    pub until: u64,
}

/// One router stall window in a plan (`router: None` stalls the chip).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StallSpec {
    pub chip: usize,
    pub router: Option<usize>,
    pub from: u64,
    pub until: u64,
}

/// One hot-spot burst: `packets` transfers converging on tile `(x, y)` of
/// `chip` at cycle `at` (sources drawn from the plan seed). Expanded into
/// the injection schedule by [`super::scenario::Scenario::schedule`], not
/// into engine ops — a burst is traffic, not link state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HotSpot {
    pub at: u64,
    pub packets: usize,
    pub chip: usize,
    pub x: usize,
    pub y: usize,
}

/// A seeded, replayable fault plan (the scenario/v1 `faults` block; see
/// EXPERIMENTS.md §Faults). The default plan is all-zero: no faults, no
/// RNG draws, bit-identical runs.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed of every per-edge corruption RNG and the hot-spot source draw.
    pub seed: u64,
    /// Bounded re-send budget per corrupted frame.
    pub max_retries: u32,
    /// Discard corrupted frames instead of retrying (the spiking-codec
    /// event-drop interpretation).
    pub drop_corrupted: bool,
    /// Uniform per-frame corruption probability across all edges.
    pub ber: f64,
    /// Per-edge overrides of `ber` (edge index -> rate).
    pub bers: BTreeMap<usize, f64>,
    /// Uniform spike-timing jitter bound (cycles) across all edges; zero
    /// disables jitter.
    pub jitter: u64,
    /// Per-edge overrides of `jitter` (edge index -> bound).
    pub jitters: BTreeMap<usize, u64>,
    pub link_down: Vec<LinkDown>,
    pub stalls: Vec<StallSpec>,
    pub hotspots: Vec<HotSpot>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            max_retries: DEFAULT_MAX_RETRIES,
            drop_corrupted: false,
            ber: 0.0,
            bers: BTreeMap::new(),
            jitter: 0,
            jitters: BTreeMap::new(),
            link_down: Vec::new(),
            stalls: Vec::new(),
            hotspots: Vec::new(),
        }
    }
}

impl FaultPlan {
    /// A plan with one uniform bit-error rate (the degradation-sweep axis).
    pub fn with_ber(seed: u64, ber: f64) -> Self {
        FaultPlan { seed, ber, ..FaultPlan::default() }
    }

    /// True when the plan cannot affect a run at all.
    pub fn is_zero(&self) -> bool {
        self.ber == 0.0
            && self.bers.values().all(|&r| r == 0.0)
            && self.jitter == 0
            && self.jitters.values().all(|&m| m == 0)
            && self.link_down.is_empty()
            && self.stalls.is_empty()
            && self.hotspots.is_empty()
    }

    fn any_link_faults(&self) -> bool {
        self.ber > 0.0
            || self.bers.values().any(|&r| r > 0.0)
            || self.jitter > 0
            || self.jitters.values().any(|&m| m > 0)
            || !self.link_down.is_empty()
    }

    /// Expand into engine ops for a topology with `n_edges` die
    /// boundaries. `Policy` is emitted first so every per-edge RNG stream
    /// is seeded before a `BitError` arrives; zero-rate edges emit nothing.
    pub fn ops(&self, n_edges: usize) -> Vec<FaultOp> {
        let mut out = Vec::new();
        if self.any_link_faults() {
            out.push(FaultOp::Policy {
                seed: self.seed,
                max_retries: self.max_retries,
                drop_corrupted: self.drop_corrupted,
            });
        }
        for e in 0..n_edges {
            let rate = self.bers.get(&e).copied().unwrap_or(self.ber);
            if rate > 0.0 {
                out.push(FaultOp::BitError { edge: e, rate });
            }
        }
        for e in 0..n_edges {
            let max = self.jitters.get(&e).copied().unwrap_or(self.jitter);
            if max > 0 {
                out.push(FaultOp::Jitter { edge: e, max });
            }
        }
        for d in &self.link_down {
            out.push(FaultOp::LinkDown { edge: d.edge, from: d.from, until: d.until });
        }
        for s in &self.stalls {
            out.push(FaultOp::Stall { chip: s.chip, router: s.router, from: s.from, until: s.until });
        }
        out
    }

    /// Validate against a topology of `chips` chips of `dim` x `dim`
    /// routers. Used by both the scenario builder (panic) and the JSON
    /// layer (error).
    pub fn validate(&self, chips: usize, dim: usize) -> Result<()> {
        let n_edges = chips.saturating_sub(1);
        let rate_ok = |r: f64| (0.0..=1.0).contains(&r);
        if !rate_ok(self.ber) {
            return Err(anyhow!("faults: ber must be in [0, 1], got {}", self.ber));
        }
        if n_edges == 0 && self.any_link_faults() {
            return Err(anyhow!("faults: link faults on a mesh topology (no EMIO edges)"));
        }
        for (&e, &r) in &self.bers {
            if e >= n_edges {
                return Err(anyhow!(
                    "faults: bers edge {e} out of range — the topology has {n_edges} die boundaries"
                ));
            }
            if !rate_ok(r) {
                return Err(anyhow!("faults: bers[{e}] must be in [0, 1], got {r}"));
            }
        }
        if self.jitter > MAX_JITTER_CYCLES {
            return Err(anyhow!(
                "faults: jitter bound {} above the {MAX_JITTER_CYCLES}-cycle cap",
                self.jitter
            ));
        }
        for (&e, &m) in &self.jitters {
            if e >= n_edges {
                return Err(anyhow!(
                    "faults: jitters edge {e} out of range — the topology has {n_edges} die \
                     boundaries"
                ));
            }
            if m > MAX_JITTER_CYCLES {
                return Err(anyhow!(
                    "faults: jitters[{e}] bound {m} above the {MAX_JITTER_CYCLES}-cycle cap"
                ));
            }
        }
        for d in &self.link_down {
            if d.edge >= n_edges {
                return Err(anyhow!(
                    "faults: link_down edge {} out of range — the topology has {n_edges} die \
                     boundaries",
                    d.edge
                ));
            }
            if d.from >= d.until {
                return Err(anyhow!(
                    "faults: link_down window needs from < until, got [{}, {})",
                    d.from,
                    d.until
                ));
            }
        }
        for s in &self.stalls {
            if s.chip >= chips {
                return Err(anyhow!(
                    "faults: stall chip {} out of range — the topology has {chips} chips",
                    s.chip
                ));
            }
            if let Some(r) = s.router {
                if r >= dim * dim {
                    return Err(anyhow!(
                        "faults: stall router {r} out of range — each chip has {} routers",
                        dim * dim
                    ));
                }
            }
            if s.from >= s.until {
                return Err(anyhow!(
                    "faults: stall window needs from < until, got [{}, {})",
                    s.from,
                    s.until
                ));
            }
        }
        for h in &self.hotspots {
            if h.chip >= chips {
                return Err(anyhow!(
                    "faults: hotspot chip {} out of range — the topology has {chips} chips",
                    h.chip
                ));
            }
            if h.x >= dim || h.y >= dim {
                return Err(anyhow!(
                    "faults: hotspot tile ({}, {}) outside the {dim} x {dim} mesh",
                    h.x,
                    h.y
                ));
            }
        }
        Ok(())
    }

    // -- JSON ---------------------------------------------------------------

    /// Serialize as the scenario/v1 `faults` block.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("seed", Json::num(self.seed as f64)),
            ("max_retries", Json::num(self.max_retries as f64)),
            ("drop_corrupted", Json::Bool(self.drop_corrupted)),
            ("ber", Json::num(self.ber)),
        ];
        if !self.bers.is_empty() {
            fields.push((
                "bers",
                Json::Obj(self.bers.iter().map(|(e, r)| (e.to_string(), Json::num(*r))).collect()),
            ));
        }
        if self.jitter != 0 {
            fields.push(("jitter", Json::num(self.jitter as f64)));
        }
        if !self.jitters.is_empty() {
            fields.push((
                "jitters",
                Json::Obj(
                    self.jitters
                        .iter()
                        .map(|(e, m)| (e.to_string(), Json::num(*m as f64)))
                        .collect(),
                ),
            ));
        }
        if !self.link_down.is_empty() {
            fields.push((
                "link_down",
                Json::arr(self.link_down.iter().map(|d| {
                    Json::obj(vec![
                        ("edge", Json::num(d.edge as f64)),
                        ("from", Json::num(d.from as f64)),
                        ("until", Json::num(d.until as f64)),
                    ])
                })),
            ));
        }
        if !self.stalls.is_empty() {
            fields.push((
                "stalls",
                Json::arr(self.stalls.iter().map(|s| {
                    let mut f = vec![("chip", Json::num(s.chip as f64))];
                    if let Some(r) = s.router {
                        f.push(("router", Json::num(r as f64)));
                    }
                    f.push(("from", Json::num(s.from as f64)));
                    f.push(("until", Json::num(s.until as f64)));
                    Json::obj(f)
                })),
            ));
        }
        if !self.hotspots.is_empty() {
            fields.push((
                "hotspots",
                Json::arr(self.hotspots.iter().map(|h| {
                    Json::obj(vec![
                        ("at", Json::num(h.at as f64)),
                        ("packets", Json::num(h.packets as f64)),
                        ("chip", Json::num(h.chip as f64)),
                        ("x", Json::num(h.x as f64)),
                        ("y", Json::num(h.y as f64)),
                    ])
                })),
            ));
        }
        Json::obj(fields)
    }

    /// Parse a `faults` block. Unknown keys are rejected (a typo'd field
    /// must not silently no-op); topology validation is the caller's job
    /// ([`FaultPlan::validate`]).
    pub fn from_json(j: &Json) -> Result<FaultPlan> {
        check_keys(
            j,
            &[
                "seed",
                "max_retries",
                "drop_corrupted",
                "ber",
                "bers",
                "jitter",
                "jitters",
                "link_down",
                "stalls",
                "hotspots",
            ],
            "faults",
        )?;
        let mut plan = FaultPlan {
            seed: opt_u64(j, "faults.seed")?.unwrap_or(0),
            max_retries: opt_u64(j, "faults.max_retries")?
                .map(|n| n as u32)
                .unwrap_or(DEFAULT_MAX_RETRIES),
            drop_corrupted: j.get("drop_corrupted").and_then(Json::as_bool).unwrap_or(false),
            ber: j.get("ber").and_then(Json::as_f64).unwrap_or(0.0),
            jitter: opt_u64(j, "faults.jitter")?.unwrap_or(0),
            ..FaultPlan::default()
        };
        if let Some(map) = j.get("bers") {
            let obj = map
                .as_obj()
                .ok_or_else(|| anyhow!("faults: bers must be an object of edge -> rate"))?;
            for (key, val) in obj {
                let e: usize = key
                    .parse()
                    .map_err(|_| anyhow!("faults: bers key {key:?} is not an edge index"))?;
                let r = val
                    .as_f64()
                    .ok_or_else(|| anyhow!("faults: bers[{key}] must be a number"))?;
                plan.bers.insert(e, r);
            }
        }
        if let Some(map) = j.get("jitters") {
            let obj = map
                .as_obj()
                .ok_or_else(|| anyhow!("faults: jitters must be an object of edge -> cycles"))?;
            for (key, val) in obj {
                let e: usize = key
                    .parse()
                    .map_err(|_| anyhow!("faults: jitters key {key:?} is not an edge index"))?;
                let m = match val.as_f64() {
                    Some(n) if n >= 0.0 && n.fract() == 0.0 => n as u64,
                    Some(n) => {
                        return Err(anyhow!(
                            "faults: jitters[{key}] must be a non-negative integer, got {n}"
                        ))
                    }
                    None => return Err(anyhow!("faults: jitters[{key}] must be a number")),
                };
                plan.jitters.insert(e, m);
            }
        }
        if let Some(arr) = j.get("link_down") {
            let items = arr
                .as_arr()
                .ok_or_else(|| anyhow!("faults: link_down must be an array of windows"))?;
            for it in items {
                check_keys(it, &["edge", "from", "until"], "faults.link_down")?;
                plan.link_down.push(LinkDown {
                    edge: req_u64(it, "faults.link_down", "edge")? as usize,
                    from: req_u64(it, "faults.link_down", "from")?,
                    until: req_u64(it, "faults.link_down", "until")?,
                });
            }
        }
        if let Some(arr) = j.get("stalls") {
            let items = arr
                .as_arr()
                .ok_or_else(|| anyhow!("faults: stalls must be an array of windows"))?;
            for it in items {
                check_keys(it, &["chip", "router", "from", "until"], "faults.stalls")?;
                let router = match it.get("router") {
                    None => None,
                    Some(_) => Some(req_u64(it, "faults.stalls", "router")? as usize),
                };
                plan.stalls.push(StallSpec {
                    chip: req_u64(it, "faults.stalls", "chip")? as usize,
                    router,
                    from: req_u64(it, "faults.stalls", "from")?,
                    until: req_u64(it, "faults.stalls", "until")?,
                });
            }
        }
        if let Some(arr) = j.get("hotspots") {
            let items = arr
                .as_arr()
                .ok_or_else(|| anyhow!("faults: hotspots must be an array of bursts"))?;
            for it in items {
                check_keys(it, &["at", "packets", "chip", "x", "y"], "faults.hotspots")?;
                plan.hotspots.push(HotSpot {
                    at: req_u64(it, "faults.hotspots", "at")?,
                    packets: req_u64(it, "faults.hotspots", "packets")? as usize,
                    chip: req_u64(it, "faults.hotspots", "chip")? as usize,
                    x: req_u64(it, "faults.hotspots", "x")? as usize,
                    y: req_u64(it, "faults.hotspots", "y")? as usize,
                });
            }
        }
        Ok(plan)
    }
}

/// Reject unknown keys in a JSON object — a typo'd `"fualts"` block or a
/// misspelled field must error, not silently no-op. Shared by the faults
/// block and the scenario top level.
pub(crate) fn check_keys(j: &Json, allowed: &[&str], ctx: &str) -> Result<()> {
    if let Some(obj) = j.as_obj() {
        for k in obj.keys() {
            if !allowed.contains(&k.as_str()) {
                return Err(anyhow!("{ctx}: unknown key {k:?} (allowed: {allowed:?})"));
            }
        }
    }
    Ok(())
}

/// Optional non-negative-integer field (rejects negatives and fractions —
/// a coerced value would silently run a different plan than the file says).
fn opt_u64(j: &Json, field: &str) -> Result<Option<u64>> {
    match j.get(field.rsplit('.').next().unwrap()).and_then(Json::as_f64) {
        None => Ok(None),
        Some(n) if n < 0.0 || n.fract() != 0.0 => {
            Err(anyhow!("{field} must be a non-negative integer, got {n}"))
        }
        Some(n) => Ok(Some(n as u64)),
    }
}

/// Required non-negative-integer field of a nested block item.
fn req_u64(j: &Json, ctx: &str, name: &str) -> Result<u64> {
    match j.get(name).and_then(Json::as_f64) {
        None => Err(anyhow!("{ctx}: {name} missing")),
        Some(n) if n < 0.0 || n.fract() != 0.0 => {
            Err(anyhow!("{ctx}: {name} must be a non-negative integer, got {n}"))
        }
        Some(n) => Ok(n as u64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_zero_and_emits_no_ops() {
        let plan = FaultPlan::default();
        assert!(plan.is_zero());
        assert!(plan.ops(4).is_empty(), "an all-zero plan must inject nothing");
        assert!(plan.validate(8, 8).is_ok());
    }

    #[test]
    fn ops_emit_policy_before_link_ops() {
        let mut plan = FaultPlan::with_ber(7, 0.1);
        plan.bers.insert(1, 0.0); // zero-rate override: edge 1 emits nothing
        plan.link_down.push(LinkDown { edge: 0, from: 10, until: 20 });
        plan.stalls.push(StallSpec { chip: 0, router: None, from: 5, until: 9 });
        let ops = plan.ops(3);
        assert!(matches!(ops[0], FaultOp::Policy { seed: 7, .. }));
        let bit_errors: Vec<usize> = ops
            .iter()
            .filter_map(|op| match op {
                FaultOp::BitError { edge, .. } => Some(*edge),
                _ => None,
            })
            .collect();
        assert_eq!(bit_errors, vec![0, 2], "edge 1's zero override is skipped");
        assert!(ops.iter().any(|op| matches!(op, FaultOp::LinkDown { edge: 0, from: 10, until: 20 })));
        assert!(ops.iter().any(|op| matches!(op, FaultOp::Stall { chip: 0, router: None, .. })));
    }

    #[test]
    fn validate_rejects_out_of_range_shapes() {
        let bad_ber = FaultPlan { ber: 1.5, ..FaultPlan::default() };
        assert!(bad_ber.validate(2, 8).is_err());
        let mesh_link = FaultPlan::with_ber(1, 0.1);
        assert!(mesh_link.validate(1, 8).is_err(), "mesh has no EMIO edges");
        let mut far_edge = FaultPlan::default();
        far_edge.link_down.push(LinkDown { edge: 1, from: 0, until: 5 });
        assert!(far_edge.validate(2, 8).is_err(), "duplex has one edge (index 0)");
        let mut empty_window = FaultPlan::default();
        empty_window.stalls.push(StallSpec { chip: 0, router: None, from: 5, until: 5 });
        assert!(empty_window.validate(1, 8).is_err(), "empty window");
        let mut far_router = FaultPlan::default();
        far_router.stalls.push(StallSpec { chip: 0, router: Some(64), from: 0, until: 5 });
        assert!(far_router.validate(1, 8).is_err(), "router index past dim^2");
        let mut far_tile = FaultPlan::default();
        far_tile.hotspots.push(HotSpot { at: 0, packets: 4, chip: 0, x: 8, y: 0 });
        assert!(far_tile.validate(1, 8).is_err(), "tile outside the mesh");
    }

    #[test]
    fn json_round_trips_exactly() {
        let mut plan = FaultPlan {
            seed: 42,
            max_retries: 2,
            drop_corrupted: true,
            ber: 0.05,
            ..FaultPlan::default()
        };
        plan.bers.insert(1, 0.25);
        plan.link_down.push(LinkDown { edge: 0, from: 100, until: 300 });
        plan.stalls.push(StallSpec { chip: 1, router: Some(9), from: 10, until: 20 });
        plan.stalls.push(StallSpec { chip: 0, router: None, from: 0, until: 4 });
        plan.hotspots.push(HotSpot { at: 50, packets: 32, chip: 2, x: 3, y: 4 });
        let back = FaultPlan::from_json(&plan.to_json()).expect("round trip parses");
        assert_eq!(back, plan);
    }

    #[test]
    fn json_rejects_unknown_and_malformed_fields() {
        let parse = |s: &str| FaultPlan::from_json(&crate::util::json::parse(s).unwrap());
        assert!(parse(r#"{"ber": 0.1, "bre": 0.2}"#).is_err(), "typo'd key");
        assert!(parse(r#"{"seed": -1}"#).is_err(), "negative seed");
        assert!(parse(r#"{"max_retries": 1.5}"#).is_err(), "fractional retries");
        assert!(parse(r#"{"link_down": [{"edge": 0, "from": 1}]}"#).is_err(), "missing until");
        assert!(parse(r#"{"link_down": [{"edge": 0, "from": 1, "till": 9}]}"#).is_err());
        assert!(parse(r#"{"stalls": [{"chip": 0, "from": 1, "until": 2, "core": 3}]}"#).is_err());
        assert!(parse(r#"{"bers": {"one": 0.1}}"#).is_err(), "non-integer edge key");
        let plan = parse(r#"{"ber": 0.1}"#).unwrap();
        assert_eq!(plan.max_retries, DEFAULT_MAX_RETRIES);
        assert!(!plan.drop_corrupted);
    }

    #[test]
    fn link_faults_retry_then_drop_when_budget_spent() {
        let mut lf = LinkFaults::new(0, 1);
        lf.set_policy(1, 2, false);
        lf.set_ber(1.0); // every crossing corrupts
        assert_eq!(lf.pad_crossing(10, 7, 0), PadVerdict::Retry);
        assert_eq!(lf.pad_crossing(11, 7, 1), PadVerdict::Retry);
        assert_eq!(lf.pad_crossing(12, 7, 2), PadVerdict::Drop, "budget of 2 spent");
        assert_eq!(lf.stats.corrupted, 3);
        assert_eq!(lf.stats.retried, 2);
        assert_eq!(lf.stats.dropped, 1);
        assert_eq!(lf.events.len(), 3);
        assert_eq!(lf.events[2].kind, FaultKind::Dropped);
        // drop_corrupted short-circuits the budget entirely
        let mut drop = LinkFaults::new(0, 1);
        drop.set_policy(1, 3, true);
        drop.set_ber(1.0);
        assert_eq!(drop.pad_crossing(0, 1, 0), PadVerdict::Drop);
    }

    #[test]
    fn zero_ber_consumes_no_rng_draws() {
        let mut a = LinkFaults::new(0, 9);
        a.set_ber(0.0);
        for i in 0..100 {
            assert_eq!(a.pad_crossing(i, i, 0), PadVerdict::Clean);
        }
        // the RNG stream is untouched: switching the rate on later yields
        // the same draws as a fresh fault state at the same rate
        a.set_ber(0.5);
        let mut b = LinkFaults::new(0, 9);
        b.set_ber(0.5);
        for i in 0..100 {
            assert_eq!(a.pad_crossing(i, i, 0), b.pad_crossing(i, i, 0));
        }
    }

    #[test]
    fn outage_blocks_pad_through_credit_recovery() {
        let mut lf = LinkFaults::new(0, 1);
        lf.add_outage(10, 20);
        assert!(!lf.pad_blocked(9));
        assert!(lf.pad_blocked(10));
        assert!(lf.pad_blocked(19));
        // the window is over, but credits are still re-establishing
        assert!(lf.pad_blocked(20));
        assert!(lf.pad_blocked(20 + CREDIT_RECOVERY_CYCLES - 1));
        assert!(!lf.pad_blocked(20 + CREDIT_RECOVERY_CYCLES));
    }

    #[test]
    fn per_edge_rng_streams_differ_but_replay() {
        assert_ne!(link_rng_seed(5, 0), link_rng_seed(5, 1));
        assert_eq!(link_rng_seed(5, 3), link_rng_seed(5, 3));
        // the jitter stream is salted away from the corruption stream
        assert_ne!(jitter_rng_seed(5, 0), link_rng_seed(5, 0));
        assert_eq!(jitter_rng_seed(5, 2), jitter_rng_seed(5, 2));
    }

    #[test]
    fn jitter_plan_expands_validates_and_round_trips() {
        let mut plan = FaultPlan { seed: 11, jitter: 6, ..FaultPlan::default() };
        plan.jitters.insert(1, 0); // per-edge zero override: edge 1 emits nothing
        assert!(!plan.is_zero());
        let ops = plan.ops(3);
        assert!(matches!(ops[0], FaultOp::Policy { seed: 11, .. }), "jitter alone needs a policy");
        let jittered: Vec<usize> = ops
            .iter()
            .filter_map(|op| match op {
                FaultOp::Jitter { edge, max: 6 } => Some(*edge),
                _ => None,
            })
            .collect();
        assert_eq!(jittered, vec![0, 2]);
        assert!(plan.validate(3, 8).is_ok());
        assert!(plan.validate(1, 8).is_err(), "mesh has no EMIO edges to jitter");
        let back = FaultPlan::from_json(&plan.to_json()).expect("round trip parses");
        assert_eq!(back, plan);
        // zero-jitter plans keep the legacy serialized form (no new keys)
        let text = FaultPlan::with_ber(1, 0.1).to_json().to_string_pretty();
        assert!(!text.contains("jitter"), "zero jitter must not serialize: {text}");
    }

    #[test]
    fn jitter_json_rejects_malformed_fields() {
        let parse = |s: &str| FaultPlan::from_json(&crate::util::json::parse(s).unwrap());
        assert!(parse(r#"{"jitter": -2}"#).is_err(), "negative bound");
        assert!(parse(r#"{"jitter": 1.5}"#).is_err(), "fractional bound");
        assert!(parse(r#"{"jitters": {"one": 4}}"#).is_err(), "non-integer edge key");
        assert!(parse(r#"{"jitters": {"0": 2.5}}"#).is_err(), "fractional per-edge bound");
        assert_eq!(parse(r#"{"jitter": 4}"#).unwrap().jitter, 4);
        let capped = FaultPlan { jitter: MAX_JITTER_CYCLES + 1, ..FaultPlan::default() };
        assert!(capped.validate(2, 8).is_err(), "bound above the cycle cap");
    }

    #[test]
    fn zero_jitter_consumes_no_rng_draws() {
        // mirror of zero_ber_consumes_no_rng_draws for the jitter stream
        let mut a = LinkFaults::new(0, 9);
        for i in 0..100 {
            assert_eq!(a.jittered_exit(i, i + 38), i + 38, "zero bound must be the identity");
        }
        a.set_jitter(5);
        let mut b = LinkFaults::new(0, 9);
        b.set_jitter(5);
        for i in 0..100 {
            assert_eq!(a.jittered_exit(i, i + 38), b.jittered_exit(i, i + 38));
        }
    }

    #[test]
    fn jittered_exit_stays_bounded_and_causal() {
        let mut lf = LinkFaults::new(0, 3);
        lf.set_jitter(4);
        let mut displaced = 0u64;
        for now in 0..500u64 {
            let base = now + 38;
            let t = lf.jittered_exit(now, base);
            assert!(t >= base - 4 && t <= base + 4, "|delta| <= max");
            assert!(t > now, "a frame never exits before the cycle after the pad");
            if t != base {
                displaced += 1;
            }
        }
        assert_eq!(lf.stats.jittered, displaced, "only non-zero displacements count");
        assert!(displaced > 0, "a +/-4 bound on 500 frames displaces some");
        // a bound wider than the pipeline depth clamps to causality
        let mut wide = LinkFaults::new(0, 1);
        wide.set_jitter(100);
        for now in 0..200u64 {
            let t = wide.jittered_exit(now, now + 38);
            assert!(t > now);
        }
    }
}
