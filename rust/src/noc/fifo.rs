//! Ring-buffer input FIFO for the cycle engine's routers.
//!
//! Replaces the five heap-allocated `VecDeque<Flit>`s the seed router
//! carried: a power-of-two ring over a flat `Vec` of packed `Copy` flits,
//! lazily allocated (an idle router owns zero heap memory) and grown by
//! doubling only when a queue actually overflows its capacity. Head/len
//! indexing keeps `front`/`pop_front`/`push_back` branch-light on the hot
//! path — see EXPERIMENTS.md §Perf.

use crate::arch::chip::Coord;

use super::router::Flit;

/// Capacity installed on the first push (power of two).
const INIT_CAP: usize = 16;

const fn zero_flit() -> Flit {
    Flit { id: 0, dest: Coord { x: 0, y: 0 }, wire: 0, injected_at: 0, hops: 0 }
}

/// A FIFO of flits backed by a power-of-two ring buffer.
#[derive(Debug, Clone, Default)]
pub struct FlitFifo {
    buf: Vec<Flit>,
    head: usize,
    len: usize,
}

impl FlitFifo {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The flit at the head of the queue, if any.
    #[inline]
    pub fn front(&self) -> Option<&Flit> {
        if self.len == 0 {
            None
        } else {
            Some(&self.buf[self.head])
        }
    }

    /// Enqueue at the tail, growing the ring if it is full.
    #[inline]
    pub fn push_back(&mut self, flit: Flit) {
        if self.len == self.buf.len() {
            self.grow();
        }
        let mask = self.buf.len() - 1;
        self.buf[(self.head + self.len) & mask] = flit;
        self.len += 1;
    }

    /// Dequeue from the head.
    #[inline]
    pub fn pop_front(&mut self) -> Option<Flit> {
        if self.len == 0 {
            return None;
        }
        let flit = self.buf[self.head];
        self.head = (self.head + 1) & (self.buf.len() - 1);
        self.len -= 1;
        if self.len == 0 {
            self.head = 0; // re-anchor: keeps long-lived queues cache-local
        }
        Some(flit)
    }

    /// Double the ring (or install the initial capacity), compacting the
    /// live span to the front.
    #[cold]
    fn grow(&mut self) {
        let old_cap = self.buf.len();
        let new_cap = (old_cap * 2).max(INIT_CAP);
        let mut next = vec![zero_flit(); new_cap];
        for (i, slot) in next.iter_mut().enumerate().take(self.len) {
            *slot = self.buf[(self.head + i) & (old_cap - 1)];
        }
        self.buf = next;
        self.head = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flit(id: u64) -> Flit {
        Flit { id, dest: Coord::new(0, 0), wire: 0, injected_at: 0, hops: 0 }
    }

    #[test]
    fn fifo_order_preserved() {
        let mut q = FlitFifo::new();
        for i in 0..5 {
            q.push_back(flit(i));
        }
        for i in 0..5 {
            assert_eq!(q.front().unwrap().id, i);
            assert_eq!(q.pop_front().unwrap().id, i);
        }
        assert!(q.pop_front().is_none());
        assert!(q.front().is_none());
    }

    #[test]
    fn empty_fifo_owns_no_heap() {
        let q = FlitFifo::new();
        assert_eq!(q.buf.capacity(), 0);
        assert!(q.is_empty());
    }

    #[test]
    fn wraps_around_the_ring() {
        let mut q = FlitFifo::new();
        // fill, half-drain, refill past the physical end repeatedly
        let mut next_push = 0u64;
        let mut next_pop = 0u64;
        for round in 0..10 {
            for _ in 0..(INIT_CAP / 2 + round) {
                q.push_back(flit(next_push));
                next_push += 1;
            }
            for _ in 0..(INIT_CAP / 2) {
                assert_eq!(q.pop_front().unwrap().id, next_pop);
                next_pop += 1;
            }
        }
        while let Some(f) = q.pop_front() {
            assert_eq!(f.id, next_pop);
            next_pop += 1;
        }
        assert_eq!(next_pop, next_push);
    }

    #[test]
    fn grows_past_initial_capacity() {
        let mut q = FlitFifo::new();
        for i in 0..(INIT_CAP as u64 * 5) {
            q.push_back(flit(i));
        }
        assert_eq!(q.len(), INIT_CAP * 5);
        for i in 0..(INIT_CAP as u64 * 5) {
            assert_eq!(q.pop_front().unwrap().id, i);
        }
    }

    #[test]
    fn fill_to_exact_capacity_without_growth() {
        // the boundary case: len == capacity is legal and must not grow
        // until the NEXT push
        let mut q = FlitFifo::new();
        for i in 0..INIT_CAP as u64 {
            q.push_back(flit(i));
        }
        assert_eq!(q.len(), INIT_CAP);
        assert_eq!(q.buf.len(), INIT_CAP, "no growth at exactly-full");
        q.push_back(flit(INIT_CAP as u64));
        assert_eq!(q.buf.len(), INIT_CAP * 2, "grow on overflow push");
        for i in 0..=(INIT_CAP as u64) {
            assert_eq!(q.pop_front().unwrap().id, i);
        }
    }

    #[test]
    fn wrap_exactly_at_capacity_boundary_without_growth() {
        // keep the queue at len == capacity across a full head revolution:
        // every slot index gets written through the mask at least once
        let mut q = FlitFifo::new();
        for i in 0..INIT_CAP as u64 {
            q.push_back(flit(i));
        }
        for round in 0..(2 * INIT_CAP as u64) {
            assert_eq!(q.pop_front().unwrap().id, round);
            q.push_back(flit(INIT_CAP as u64 + round)); // back to exactly full
            assert_eq!(q.len(), INIT_CAP);
            assert_eq!(q.buf.len(), INIT_CAP, "steady-state full must not grow");
        }
        for i in 0..INIT_CAP as u64 {
            assert_eq!(q.pop_front().unwrap().id, 2 * INIT_CAP as u64 + i);
        }
    }

    #[test]
    fn head_reanchors_to_zero_on_empty() {
        let mut q = FlitFifo::new();
        for i in 0..5u64 {
            q.push_back(flit(i));
        }
        for _ in 0..5 {
            q.pop_front();
        }
        assert!(q.is_empty());
        assert_eq!(q.head, 0, "empty queue must re-anchor for cache locality");
        // and keeps working afterwards
        q.push_back(flit(99));
        assert_eq!(q.pop_front().unwrap().id, 99);
    }

    #[test]
    fn growth_mid_wrap_keeps_order() {
        let mut q = FlitFifo::new();
        for i in 0..INIT_CAP as u64 {
            q.push_back(flit(i));
        }
        for i in 0..(INIT_CAP as u64 / 2) {
            assert_eq!(q.pop_front().unwrap().id, i);
        }
        // tail now wraps; pushing past capacity forces a compacting grow
        for i in 0..(2 * INIT_CAP as u64) {
            q.push_back(flit(1_000 + i));
        }
        for i in (INIT_CAP as u64 / 2)..INIT_CAP as u64 {
            assert_eq!(q.pop_front().unwrap().id, i);
        }
        for i in 0..(2 * INIT_CAP as u64) {
            assert_eq!(q.pop_front().unwrap().id, 1_000 + i);
        }
    }
}
