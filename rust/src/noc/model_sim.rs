//! Model-driven cycle simulation: push a partitioned network's boundary
//! traffic through the [`Chain`] simulator and compare against the
//! analytic Eq. 8/9 EMIO model — the cross-validation loop of Fig. 6.
//!
//! Full-scale traffic for EfficientNet-B4 would be billions of packets, so
//! edges are *sampled*: each boundary edge contributes up to `cap` packets
//! and the measured cycles are compared to the analytic cycles for the
//! same sampled count (both models see identical traffic, so the ratio is
//! meaningful at any sample size).

// cycle and layer bookkeeping narrows deliberately within engine bounds
#![allow(clippy::cast_possible_truncation)]

use crate::analytic::latency;
use crate::arch::chip::Coord;
use crate::arch::params::ArchConfig;
use crate::model::layer::Network;
use crate::model::mapping::map_network;
use crate::model::partition::partition;
use crate::sparsity::SparsityProfile;
use crate::util::rng::Rng;

use super::chain::{Chain, ChainTraffic};

/// Comparison record for one boundary edge.
#[derive(Debug, Clone)]
pub struct EdgeValidation {
    pub layer_idx: usize,
    pub sampled_packets: u64,
    pub crossings: usize,
    /// cycle-level measured drain cycles for the sampled traffic
    pub measured_cycles: u64,
    /// analytic Eq. 8 cycles for the same packet count (x crossings)
    pub analytic_cycles: u64,
}

impl EdgeValidation {
    /// Measured-over-analytic cycle ratio for the sampled edge. Eq. 8 is a
    /// first-order throughput model and the cycle sim adds mesh + merge
    /// queueing, so agreement means a small constant band around 1.0 — the
    /// documented tolerance is **0.2 <= ratio < 5.0** in either direction
    /// (asserted by the tests below); 1.0 when there is nothing to compare.
    pub fn ratio(&self) -> f64 {
        if self.analytic_cycles == 0 {
            return 1.0;
        }
        self.measured_cycles as f64 / self.analytic_cycles as f64
    }
}

/// The documented [`EdgeValidation::ratio`] tolerance band.
pub const RATIO_BAND: std::ops::Range<f64> = 0.2..5.0;

/// Validate every boundary edge of a (network, config, profile) triple.
pub fn validate_boundary_edges(
    net: &Network,
    cfg: &ArchConfig,
    profile: &SparsityProfile,
    cap: u64,
    seed: u64,
) -> Vec<EdgeValidation> {
    let mapping = map_network(net, cfg);
    let part = partition(net, &mapping, cfg);
    let works = crate::analytic::workload::layer_workloads(net, &mapping, &part, cfg, profile);
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();

    for w in &works {
        if w.die_crossings == 0 || w.local_packets == 0 {
            continue;
        }
        let sampled = w.local_packets.min(cap);
        // cycle-level: run the sampled packets across `crossings` dies
        let n_chips = w.die_crossings + 1;
        let mut chain = Chain::new(n_chips.min(8), cfg.noc_dim);
        let dest_chip = (n_chips - 1).min(7);
        for i in 0..sampled {
            let row = (i % cfg.noc_dim as u64) as usize;
            let spread = rng.range(0, cfg.noc_dim);
            chain.inject(ChainTraffic {
                src_chip: 0,
                src: Coord::new(cfg.noc_dim - 1, row),
                dest_chip,
                dest: Coord::new(spread, row),
            });
        }
        let stats = chain.run(200_000_000);
        debug_assert_eq!(stats.delivered, sampled);

        let nc = w.cores.min(cfg.emio_pad_ports()).max(1);
        let analytic = latency::emio_cycles(sampled, nc) * dest_chip as u64;
        out.push(EdgeValidation {
            layer_idx: w.layer_idx,
            sampled_packets: sampled,
            crossings: dest_chip,
            measured_cycles: stats.cycles,
            analytic_cycles: analytic.max(1),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::params::Variant;
    use crate::model::networks;

    #[test]
    fn msresnet_boundary_edges_within_3x_of_analytic() {
        // Eq. 8 is a first-order throughput model; the cycle sim adds mesh
        // queueing. Each sampled edge must land within a small constant
        // factor, in either direction.
        let net = networks::msresnet18();
        let cfg = ArchConfig::baseline(Variant::Hnn);
        let profile = SparsityProfile::uniform(net.layers.len(), 0.1);
        let vals = validate_boundary_edges(&net, &cfg, &profile, 512, 7);
        assert!(!vals.is_empty(), "MS-ResNet18 must have boundary edges");
        for v in &vals {
            let r = v.ratio();
            assert!(
                RATIO_BAND.contains(&r),
                "layer {}: measured {} vs analytic {} (ratio {r})",
                v.layer_idx,
                v.measured_cycles,
                v.analytic_cycles
            );
        }
    }

    /// 100 one-core 256-neuron layers -> 2 chips, exactly one boundary edge.
    fn hand_built_net() -> Network {
        use crate::model::layer::{Layer, LayerKind};
        Network {
            name: "t".into(),
            layers: (0..100)
                .map(|i| Layer::new(format!("l{i}"), LayerKind::Dense { in_f: 256, out_f: 256 }))
                .collect(),
        }
    }

    #[test]
    fn ratio_stays_in_the_documented_band_on_a_hand_built_network() {
        // one hand-checkable edge per variant: the measured/analytic ratio
        // must sit inside the documented 0.2..5.0 tolerance band, and the
        // degenerate no-analytic case pins ratio() to exactly 1.0
        let net = hand_built_net();
        let profile = SparsityProfile::uniform(100, 0.1);
        for variant in [Variant::Ann, Variant::Hnn] {
            let cfg = ArchConfig::baseline(variant);
            let vals = validate_boundary_edges(&net, &cfg, &profile, u64::MAX, 5);
            assert_eq!(vals.len(), 1, "{variant}: exactly one boundary edge");
            let v = &vals[0];
            assert_eq!(v.crossings, 1);
            assert!(v.measured_cycles >= 76, "a crossing pays the SerDes floor");
            assert!(
                RATIO_BAND.contains(&v.ratio()),
                "{variant}: measured {} vs analytic {} (ratio {})",
                v.measured_cycles,
                v.analytic_cycles,
                v.ratio()
            );
        }
        let degenerate = EdgeValidation {
            layer_idx: 0,
            sampled_packets: 0,
            crossings: 0,
            measured_cycles: 123,
            analytic_cycles: 0,
        };
        assert_eq!(degenerate.ratio(), 1.0);
    }

    #[test]
    fn cap_sampling_is_deterministic_in_seed() {
        // the cap truncates each edge to `cap` sampled packets, and the
        // whole validation — sampled counts, measured cycles, ratios — is a
        // pure function of the seed
        let net = hand_built_net();
        let cfg = ArchConfig::baseline(Variant::Hnn);
        let profile = SparsityProfile::uniform(100, 0.1);
        let run = |cap, seed| validate_boundary_edges(&net, &cfg, &profile, cap, seed);

        let a = run(64, 9);
        let b = run(64, 9);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.sampled_packets, y.sampled_packets);
            assert_eq!(x.measured_cycles, y.measured_cycles, "same seed, same cycles");
            assert_eq!(x.analytic_cycles, y.analytic_cycles);
        }
        assert!(a.iter().all(|v| v.sampled_packets == 64), "cap 64 truncates the 205-packet edge");
        // uncapped, the edge samples its full analytic count (205 at 10%)
        let full = run(u64::MAX, 9);
        assert_eq!(full[0].sampled_packets, 205);
        // a different seed spreads destinations differently but never
        // changes how many packets the cap admits
        let c = run(64, 10);
        assert_eq!(c[0].sampled_packets, 64);
    }

    #[test]
    fn hnn_sampled_traffic_below_ann() {
        // 100 one-core dense layers -> exactly one die crossing whose edge
        // carries 256 dense packets (ANN) vs 205 spike packets (HNN);
        // an uncapping sample must preserve that ratio.
        use crate::model::layer::{Layer, LayerKind};
        let net = Network {
            name: "t".into(),
            layers: (0..100)
                .map(|i| Layer::new(format!("l{i}"), LayerKind::Dense { in_f: 256, out_f: 256 }))
                .collect(),
        };
        let profile = SparsityProfile::uniform(net.layers.len(), 0.1);
        let sum = |variant| {
            let cfg = ArchConfig::baseline(variant);
            validate_boundary_edges(&net, &cfg, &profile, u64::MAX, 3)
                .iter()
                .map(|v| v.sampled_packets)
                .sum::<u64>()
        };
        assert_eq!(sum(Variant::Ann), 256);
        assert_eq!(sum(Variant::Hnn), 205);
    }
}
