//! EMIO cycle-level model (§3.4, Fig. 3): merge block -> SerDes -> pad ->
//! (die gap) -> deserializer -> split block.
//!
//! One [`EmioLink`] models one chip side's unidirectional egress:
//!
//! * 8 **serializer** lanes (one per boundary core feeding the side), each
//!   shifting one 38-bit frame out over [`SER_CYCLES`] cycles — they run in
//!   parallel, matching "the serialization process occurs in parallel
//!   across the 8 peripheral ports";
//! * an 8-to-1 **merge/mux** onto the pad, draining one completed frame per
//!   cycle (round-robin over ready lanes, asynchronous-FIFO-buffered in the
//!   RTL — a queue here);
//! * a pipelined **deserializer**: a frame entering the pad appears at the
//!   split block [`DES_CYCLES`] cycles later; throughput one frame/cycle.
//!
//! A lone frame therefore crosses in `38 + 38 = 76` cycles — the synthesized
//! RTL figure the paper reports.
//!
//! **Fault injection** (see [`super::faults`]): a link optionally carries a
//! [`LinkFaults`] state. During a link-down window (plus credit recovery)
//! the pad transmits nothing; a frame crossing the pad may be corrupted by
//! the seeded bit-error RNG and is then either re-sent through the merge
//! FIFO (bounded retries — the fault costs latency, not the packet) or
//! dropped. A link without fault state (`faults: None`, the default) runs
//! the exact pre-fault fast path, bit-identically. Both engine families
//! share this one implementation, so they stay in lockstep under identical
//! fault plans by construction.

// lane/frame bookkeeping narrows deliberately; frame counts are bounded
// by the pad geometry
#![allow(clippy::cast_possible_truncation)]

use std::collections::VecDeque;

use crate::arch::packet::Packet;

use super::faults::{FaultEvent, FaultStats, LinkFaults, PadVerdict};

/// SerDes serialization depth (cycles per frame in a lane).
pub const SER_CYCLES: u64 = 38;
/// Deserializer pipeline depth (cycles from pad to split block).
pub const DES_CYCLES: u64 = 38;
/// Serializer lanes per chip side (8 boundary cores feed one pad).
pub const LANES: usize = 8;

/// A frame in flight across the die gap.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    /// Tagged 38-bit word (packet + 3-bit origin port).
    pub wire: u64,
    /// Opaque payload id for tracking.
    pub id: u64,
    /// Cycle the frame entered a serializer lane.
    pub entered_at: u64,
    /// Times this frame was re-sent after pad corruption (0 on a clean
    /// link; bounded by the fault policy's retry budget).
    pub retries: u32,
}

#[derive(Debug, Clone)]
struct SerLane {
    /// Frame being shifted out and the cycle it completes.
    busy_until: u64,
    current: Option<Frame>,
    queue: VecDeque<Frame>,
}

/// One unidirectional die-to-die link.
#[derive(Debug, Clone)]
pub struct EmioLink {
    lanes: Vec<SerLane>,
    /// Merge FIFO of fully-serialized frames waiting for the pad.
    merge: VecDeque<Frame>,
    /// (frame, cycle it exits the deserializer).
    in_flight: VecDeque<(Frame, u64)>,
    /// Frames delivered to the split block on the far die.
    pub delivered: Vec<(Frame, u64)>,
    /// Round-robin pointer over lanes for merge arbitration.
    rr: usize,
    /// Total frames accepted.
    pub accepted: u64,
    /// Fault state; `None` (the default) is the pristine fast path.
    faults: Option<LinkFaults>,
}

impl Default for EmioLink {
    fn default() -> Self {
        Self::new()
    }
}

impl EmioLink {
    pub fn new() -> Self {
        EmioLink {
            lanes: (0..LANES)
                .map(|_| SerLane { busy_until: 0, current: None, queue: VecDeque::new() })
                .collect(),
            merge: VecDeque::new(),
            in_flight: VecDeque::new(),
            delivered: Vec::new(),
            rr: 0,
            accepted: 0,
            faults: None,
        }
    }

    fn faults_mut(&mut self, edge: usize) -> &mut LinkFaults {
        self.faults.get_or_insert_with(|| LinkFaults::new(edge, 0))
    }

    /// Seed the corruption RNG of this link (die boundary `edge`) and set
    /// the retry policy. Must precede `set_ber` for a replayable stream —
    /// [`super::faults::FaultPlan::ops`] guarantees the order.
    pub fn fault_policy(&mut self, edge: usize, seed: u64, max_retries: u32, drop_corrupted: bool) {
        self.faults_mut(edge).set_policy(seed, max_retries, drop_corrupted);
    }

    /// Set the per-frame corruption probability of this link.
    pub fn set_ber(&mut self, edge: usize, rate: f64) {
        self.faults_mut(edge).set_ber(rate);
    }

    /// Set the spike-timing jitter bound of this link: clean frames exit
    /// the deserializer displaced by a seeded draw in `[-max, +max]`
    /// cycles. The in-flight pipeline drains in FIFO order, so jitter is
    /// order-preserving per link — a displaced frame delays, never
    /// overtakes.
    pub fn set_jitter(&mut self, edge: usize, max: u64) {
        self.faults_mut(edge).set_jitter(max);
    }

    /// Add a `[from, until)` outage window to this link.
    pub fn add_outage(&mut self, edge: usize, from: u64, until: u64) {
        self.faults_mut(edge).add_outage(from, until);
    }

    /// Fault counters of this link (zero when no fault state exists).
    pub fn fault_stats(&self) -> FaultStats {
        self.faults.as_ref().map(|f| f.stats).unwrap_or_default()
    }

    /// Per-incident fault events of this link (empty when clean).
    pub fn fault_events(&self) -> &[FaultEvent] {
        self.faults.as_ref().map(|f| f.events.as_slice()).unwrap_or(&[])
    }

    /// Offer a packet to boundary lane `lane` (the source boundary core's
    /// port index, 0..8) at cycle `now`.
    pub fn inject(&mut self, lane: usize, pkt: &Packet, id: u64, now: u64) {
        let lane = lane % LANES;
        self.lanes[lane].queue.push_back(Frame {
            wire: pkt.encode_d2d(lane as u8),
            id,
            entered_at: now,
            retries: 0,
        });
        self.accepted += 1;
    }

    /// Advance one clock cycle.
    pub fn step(&mut self, now: u64) {
        // 1. serializer lanes: start a new frame when idle; finish shifts.
        for lane in self.lanes.iter_mut() {
            if lane.current.is_none() {
                if let Some(f) = lane.queue.pop_front() {
                    // the shift occupies SER_CYCLES clocks including this one
                    lane.busy_until = now + SER_CYCLES - 1;
                    lane.current = Some(f);
                }
            }
        }
        // completed serializations move to the merge FIFO
        for lane in self.lanes.iter_mut() {
            if lane.current.is_some() && now >= lane.busy_until {
                self.merge.push_back(lane.current.take().unwrap());
            }
        }
        // 2. pad: one frame per cycle leaves the merge FIFO and enters the
        //    deserializer pipeline (round-robin is inherent in FIFO order;
        //    rr retained for lane fairness bookkeeping). During an outage
        //    window (plus credit recovery) the pad transmits nothing; a
        //    crossing frame may be corrupted and then retried or dropped.
        self.rr = (self.rr + 1) % LANES;
        match &mut self.faults {
            Some(lf) if lf.pad_blocked(now) => lf.note_blocked_cycle(),
            Some(lf) => {
                if let Some(mut f) = self.merge.pop_front() {
                    match lf.pad_crossing(now, f.id, f.retries) {
                        PadVerdict::Clean => {
                            let exit = lf.jittered_exit(now, now + DES_CYCLES);
                            self.in_flight.push_back((f, exit));
                        }
                        PadVerdict::Retry => {
                            f.retries += 1;
                            self.merge.push_back(f);
                        }
                        PadVerdict::Drop => {}
                    }
                }
            }
            None => {
                if let Some(f) = self.merge.pop_front() {
                    self.in_flight.push_back((f, now + DES_CYCLES));
                }
            }
        }
        // 3. deserializer exit: deliver everything whose pipeline time is up
        while let Some((_, t)) = self.in_flight.front() {
            if *t <= now {
                let (f, _) = self.in_flight.pop_front().unwrap();
                self.delivered.push((f, now));
            } else {
                break;
            }
        }
    }

    /// Frames still inside the link.
    pub fn pending(&self) -> usize {
        self.lanes.iter().map(|l| l.queue.len() + l.current.is_some() as usize).sum::<usize>()
            + self.merge.len()
            + self.in_flight.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::packet::Packet;

    fn run_until_empty(link: &mut EmioLink, start: u64) -> u64 {
        let mut now = start;
        while link.pending() > 0 {
            now += 1;
            link.step(now);
            assert!(now < start + 1_000_000, "link wedged");
        }
        now
    }

    #[test]
    fn single_packet_crosses_in_76_cycles() {
        // The §3.4 RTL claim: one packet, die-to-die, 76 cycles.
        let mut link = EmioLink::new();
        let p = Packet::spike(1, 0, 7, 3);
        link.inject(0, &p, 42, 0);
        let done = run_until_empty(&mut link, 0);
        assert_eq!(link.delivered.len(), 1);
        let (frame, at) = &link.delivered[0];
        assert_eq!(*at, done);
        assert_eq!(*at - frame.entered_at, SER_CYCLES + DES_CYCLES); // 76
        // codec fidelity across the link
        let (decoded, port) = Packet::decode_d2d(frame.wire);
        assert_eq!(decoded, p);
        assert_eq!(port, 0);
    }

    #[test]
    fn parallel_lanes_serialize_concurrently() {
        // 8 packets on 8 lanes: all serialize in parallel; the pad drains
        // one per cycle; total time ~ 76 + 7, NOT 8 x 76.
        let mut link = EmioLink::new();
        for lane in 0..8 {
            link.inject(lane, &Packet::spike(1, 0, lane as u8, 0), lane as u64, 0);
        }
        let done = run_until_empty(&mut link, 0);
        assert_eq!(link.delivered.len(), 8);
        assert!(done <= 76 + 8, "done={done}");
    }

    #[test]
    fn single_lane_is_serialization_bound() {
        // 4 packets on ONE lane: each waits a full 38-cycle shift:
        // last delivery >= 4*38 + 38.
        let mut link = EmioLink::new();
        for i in 0..4 {
            link.inject(0, &Packet::spike(1, 0, 0, 0), i, 0);
        }
        let done = run_until_empty(&mut link, 0);
        assert!(done >= 4 * SER_CYCLES + DES_CYCLES, "done={done}");
    }

    #[test]
    fn pipelined_throughput_approaches_one_per_cycle() {
        // Saturate all lanes with many packets: steady-state throughput is
        // bounded by the pad at 1 frame/cycle but must beat 1 per 38.
        let mut link = EmioLink::new();
        let n = 400u64;
        for i in 0..n {
            link.inject((i % 8) as usize, &Packet::spike(1, 0, 0, 0), i, 0);
        }
        let done = run_until_empty(&mut link, 0);
        // lower bound: lanes serialize 50 frames each = 50*38 = 1900;
        // upper bound must be far below the fully-serial 400*76.
        assert!(done < n * 40, "done={done}");
        assert_eq!(link.delivered.len(), n as usize);
    }

    #[test]
    fn delivery_preserves_per_lane_order() {
        let mut link = EmioLink::new();
        for i in 0..10 {
            link.inject(3, &Packet::activation(1, 0, i as u8, 0), i, 0);
        }
        run_until_empty(&mut link, 0);
        let ids: Vec<u64> = link.delivered.iter().map(|(f, _)| f.id).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted);
    }

    #[test]
    fn outage_delays_but_never_loses_frames() {
        use crate::noc::faults::CREDIT_RECOVERY_CYCLES;
        let mut clean = EmioLink::new();
        let mut faulty = EmioLink::new();
        let p = Packet::spike(1, 0, 7, 3);
        // outage covers the cycle the lone frame would cross the pad
        faulty.add_outage(0, SER_CYCLES, SER_CYCLES + 100);
        clean.inject(0, &p, 1, 0);
        faulty.inject(0, &p, 1, 0);
        let clean_done = run_until_empty(&mut clean, 0);
        let faulty_done = run_until_empty(&mut faulty, 0);
        assert_eq!(faulty.delivered.len(), 1, "an outage must not lose the frame");
        assert!(
            faulty_done >= clean_done + 100 && faulty_done <= clean_done + 100 + CREDIT_RECOVERY_CYCLES + 1,
            "clean={clean_done} faulty={faulty_done}"
        );
        assert!(faulty.fault_stats().link_down_cycles > 0);
    }

    #[test]
    fn certain_corruption_retries_until_budget_then_drops() {
        let mut link = EmioLink::new();
        link.fault_policy(0, 1, 2, false);
        link.set_ber(0, 1.0); // every pad crossing corrupts
        link.inject(0, &Packet::spike(1, 0, 0, 0), 9, 0);
        run_until_empty(&mut link, 0);
        assert!(link.delivered.is_empty(), "certain corruption must eventually drop");
        let fs = link.fault_stats();
        assert_eq!((fs.corrupted, fs.retried, fs.dropped), (3, 2, 1));
        assert_eq!(link.fault_events().len(), 3);
    }

    #[test]
    fn drop_corrupted_discards_on_first_corruption() {
        let mut link = EmioLink::new();
        link.fault_policy(0, 1, 3, true);
        link.set_ber(0, 1.0);
        link.inject(0, &Packet::spike(1, 0, 0, 0), 9, 0);
        run_until_empty(&mut link, 0);
        assert!(link.delivered.is_empty());
        let fs = link.fault_stats();
        assert_eq!((fs.corrupted, fs.retried, fs.dropped), (1, 0, 1));
    }

    #[test]
    fn zero_rate_fault_state_is_behavior_neutral() {
        // fault state with an all-zero plan must not change delivery timing
        let mut clean = EmioLink::new();
        let mut zeroed = EmioLink::new();
        zeroed.fault_policy(0, 42, 3, false);
        zeroed.set_ber(0, 0.0);
        for i in 0..20 {
            let p = Packet::spike(1, 0, (i % 8) as u8, 0);
            clean.inject(i as usize % 8, &p, i, 0);
            zeroed.inject(i as usize % 8, &p, i, 0);
        }
        let a = run_until_empty(&mut clean, 0);
        let b = run_until_empty(&mut zeroed, 0);
        assert_eq!(a, b);
        assert_eq!(clean.delivered, zeroed.delivered);
        assert!(zeroed.fault_stats().is_zero());
    }

    #[test]
    fn zero_jitter_state_is_behavior_neutral() {
        // a configured-but-zero jitter bound must not change delivery
        // timing or consume a single draw (mirror of the zero-ber test)
        let mut clean = EmioLink::new();
        let mut zeroed = EmioLink::new();
        zeroed.fault_policy(0, 42, 3, false);
        zeroed.set_jitter(0, 0);
        for i in 0..20 {
            let p = Packet::spike(1, 0, (i % 8) as u8, 0);
            clean.inject(i as usize % 8, &p, i, 0);
            zeroed.inject(i as usize % 8, &p, i, 0);
        }
        let a = run_until_empty(&mut clean, 0);
        let b = run_until_empty(&mut zeroed, 0);
        assert_eq!(a, b);
        assert_eq!(clean.delivered, zeroed.delivered);
        assert!(zeroed.fault_stats().is_zero());
    }

    #[test]
    fn jitter_displaces_timing_but_never_loses_or_reorders_frames() {
        let mut clean = EmioLink::new();
        let mut jittered = EmioLink::new();
        jittered.fault_policy(0, 7, 3, false);
        jittered.set_jitter(0, 6);
        for i in 0..40 {
            let p = Packet::spike(1, 0, (i % 8) as u8, 0);
            clean.inject(i as usize % 8, &p, i, 0);
            jittered.inject(i as usize % 8, &p, i, 0);
        }
        run_until_empty(&mut clean, 0);
        run_until_empty(&mut jittered, 0);
        // jitter costs timing, never packets, and the pipeline stays FIFO
        assert_eq!(jittered.delivered.len(), clean.delivered.len());
        let ids: Vec<u64> = jittered.delivered.iter().map(|(f, _)| f.id).collect();
        let clean_ids: Vec<u64> = clean.delivered.iter().map(|(f, _)| f.id).collect();
        assert_eq!(ids, clean_ids, "jitter must be order-preserving per link");
        let fs = jittered.fault_stats();
        assert!(fs.jittered > 0, "a +/-6 bound over 40 frames displaces some");
        assert_eq!((fs.corrupted, fs.dropped), (0, 0));
        // at least one frame actually moved relative to the clean run
        let moved = clean
            .delivered
            .iter()
            .zip(&jittered.delivered)
            .any(|((_, a), (_, b))| a != b);
        assert!(moved, "the displaced draws must be visible in delivery cycles");
        // and the same seed replays bit-identically
        let mut replay = EmioLink::new();
        replay.fault_policy(0, 7, 3, false);
        replay.set_jitter(0, 6);
        for i in 0..40 {
            let p = Packet::spike(1, 0, (i % 8) as u8, 0);
            replay.inject(i as usize % 8, &p, i, 0);
        }
        run_until_empty(&mut replay, 0);
        assert_eq!(replay.delivered, jittered.delivered);
    }
}
