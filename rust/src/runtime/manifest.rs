//! `artifacts/manifest.json` loader: the contract between the AOT pipeline
//! (python, build-time) and the rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};

/// dtype of a tensor in the manifest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => Err(anyhow!("unsupported dtype {other}")),
        }
    }
}

/// One tensor signature.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One exported computation (train/eval/predict or a kernel micro-fn).
#[derive(Debug, Clone)]
pub struct FnEntry {
    pub hlo_path: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// One trained model family x variant.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub param_count: usize,
    pub n_rates: usize,
    pub boundary_blocks: Vec<usize>,
    pub init_theta: PathBuf,
    pub fns: BTreeMap<String, FnEntry>,
    /// Raw config block (family, variant, dims, ticks, ...).
    pub config: Json,
}

impl ModelEntry {
    pub fn family(&self) -> &str {
        self.config.get("family").and_then(|j| j.as_str()).unwrap_or("?")
    }

    pub fn variant(&self) -> &str {
        self.config.get("variant").and_then(|j| j.as_str()).unwrap_or("?")
    }

    pub fn cfg_usize(&self, key: &str) -> Option<usize> {
        self.config.get(key).and_then(|j| j.as_usize())
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelEntry>,
    pub kernels: BTreeMap<String, FnEntry>,
}

fn parse_sigs(j: &Json) -> Result<Vec<TensorSig>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("signature is not an array"))?
        .iter()
        .map(|e| {
            Ok(TensorSig {
                name: e.get("name").and_then(|x| x.as_str()).unwrap_or("").to_string(),
                shape: e
                    .get("shape")
                    .and_then(|x| x.as_shape())
                    .ok_or_else(|| anyhow!("bad shape"))?,
                dtype: DType::parse(
                    e.get("dtype").and_then(|x| x.as_str()).unwrap_or("float32"),
                )?,
            })
        })
        .collect()
}

fn parse_fn(dir: &Path, j: &Json) -> Result<FnEntry> {
    Ok(FnEntry {
        hlo_path: dir.join(j.get("hlo").and_then(|x| x.as_str()).ok_or_else(|| anyhow!("no hlo"))?),
        inputs: parse_sigs(j.get("inputs").ok_or_else(|| anyhow!("no inputs"))?)?,
        outputs: parse_sigs(j.get("outputs").ok_or_else(|| anyhow!("no outputs"))?)?,
    })
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let root = json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;

        let mut models = BTreeMap::new();
        if let Some(m) = root.get("models").and_then(|j| j.as_obj()) {
            for (name, entry) in m {
                let mut fns = BTreeMap::new();
                if let Some(fmap) = entry.get("fns").and_then(|j| j.as_obj()) {
                    for (fname, fj) in fmap {
                        fns.insert(fname.clone(), parse_fn(&dir, fj)?);
                    }
                }
                models.insert(
                    name.clone(),
                    ModelEntry {
                        name: name.clone(),
                        param_count: entry
                            .get("param_count")
                            .and_then(|j| j.as_usize())
                            .ok_or_else(|| anyhow!("{name}: no param_count"))?,
                        n_rates: entry.get("n_rates").and_then(|j| j.as_usize()).unwrap_or(1),
                        boundary_blocks: entry
                            .get("boundary_blocks")
                            .and_then(|j| j.as_shape())
                            .unwrap_or_default(),
                        init_theta: dir.join(
                            entry
                                .get("init_theta")
                                .and_then(|j| j.as_str())
                                .ok_or_else(|| anyhow!("{name}: no init_theta"))?,
                        ),
                        fns,
                        config: entry.get("config").cloned().unwrap_or(Json::Null),
                    },
                );
            }
        }

        let mut kernels = BTreeMap::new();
        if let Some(k) = root.get("kernels").and_then(|j| j.as_obj()) {
            for (name, entry) in k {
                kernels.insert(name.clone(), parse_fn(&dir, entry)?);
            }
        }

        Ok(Manifest { dir, models, kernels })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name} not in manifest (have: {:?})", self.models.keys()))
    }

    pub fn kernel(&self, name: &str) -> Result<&FnEntry> {
        self.kernels.get(name).ok_or_else(|| anyhow!("kernel {name} not in manifest"))
    }

    /// Load the initial flat parameter vector for a model.
    pub fn load_init_theta(&self, model: &ModelEntry) -> Result<Vec<f32>> {
        let bytes = std::fs::read(&model.init_theta)
            .with_context(|| format!("reading {:?}", model.init_theta))?;
        if bytes.len() != model.param_count * 4 {
            return Err(anyhow!(
                "init theta size mismatch: {} bytes for {} params",
                bytes.len(),
                model.param_count
            ));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if d.join("manifest.json").exists() {
            Some(d)
        } else {
            None
        }
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let Some(dir) = artifacts_dir() else { return };
        let man = Manifest::load(&dir).unwrap();
        assert!(!man.kernels.is_empty());
        for (name, k) in &man.kernels {
            assert!(k.hlo_path.exists(), "{name} hlo missing");
            assert!(!k.inputs.is_empty());
        }
        for (name, m) in &man.models {
            assert!(m.param_count > 0, "{name}");
            assert!(m.init_theta.exists(), "{name} theta missing");
            let theta = man.load_init_theta(m).unwrap();
            assert_eq!(theta.len(), m.param_count);
            for fn_name in ["train", "eval", "predict"] {
                assert!(m.fns.contains_key(fn_name), "{name}.{fn_name}");
            }
        }
    }

    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join(format!("slman-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"kernels": {"k1": {"hlo": "k1.hlo.txt",
                "inputs": [{"name": "x", "shape": [2, 3], "dtype": "float32"}],
                "outputs": [{"name": "y", "shape": [2], "dtype": "int32"}]}},
               "models": {}}"#,
        )
        .unwrap();
        let man = Manifest::load(&dir).unwrap();
        let k = man.kernel("k1").unwrap();
        assert_eq!(k.inputs[0].shape, vec![2, 3]);
        assert_eq!(k.inputs[0].elements(), 6);
        assert_eq!(k.outputs[0].dtype, DType::I32);
        assert!(man.kernel("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
