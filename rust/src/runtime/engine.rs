//! PJRT execution engine: load AOT HLO-text artifacts, compile once on the
//! CPU PJRT client, execute from the rust hot path. Compiled only with
//! `--features xla` (needs the external PJRT bindings); the default build
//! substitutes `stub.rs` with the same API surface.
//!
//! Adapted from /opt/xla-example/load_hlo — HLO *text* is the interchange
//! format (xla_extension 0.5.1 rejects jax>=0.5 serialized protos whose
//! instruction ids exceed INT_MAX; the text parser reassigns ids).

// buffer sizes and element counts narrow within artifact-declared shapes
#![allow(clippy::cast_possible_truncation)]

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use super::manifest::{DType, FnEntry, TensorSig};
use super::tensor::Tensor;

fn literal_of(sig: &TensorSig, t: &Tensor) -> Result<xla::Literal> {
    if t.len() != sig.elements() {
        return Err(anyhow!(
            "input {}: got {} elements, signature wants {:?}",
            sig.name,
            t.len(),
            sig.shape
        ));
    }
    let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
    let lit = match (t, sig.dtype) {
        (Tensor::F32(v), DType::F32) => xla::Literal::vec1(v.as_slice()),
        (Tensor::I32(v), DType::I32) => xla::Literal::vec1(v.as_slice()),
        _ => return Err(anyhow!("input {}: dtype mismatch", sig.name)),
    };
    if dims.is_empty() {
        // scalar: vec1 of length 1 -> reshape to rank-0
        Ok(lit.reshape(&[])?)
    } else {
        Ok(lit.reshape(&dims)?)
    }
}

fn tensor_of(sig: &TensorSig, lit: &xla::Literal) -> Result<Tensor> {
    let out = match sig.dtype {
        DType::F32 => Tensor::F32(lit.to_vec::<f32>()?),
        DType::I32 => Tensor::I32(lit.to_vec::<i32>()?),
    };
    if out.len() != sig.elements() {
        return Err(anyhow!(
            "output {}: got {} elements, signature wants {:?}",
            sig.name,
            out.len(),
            sig.shape
        ));
    }
    Ok(out)
}

/// A compiled computation with its I/O signature.
pub struct Executable {
    pub name: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Run with host tensors; returns host tensors (tuple outputs
    /// decomposed per the manifest signature).
    pub fn run(&self, args: &[Tensor]) -> Result<Vec<Tensor>> {
        if args.len() != self.inputs.len() {
            return Err(anyhow!(
                "{}: got {} args, expected {}",
                self.name,
                args.len(),
                self.inputs.len()
            ));
        }
        let lits: Vec<xla::Literal> = self
            .inputs
            .iter()
            .zip(args)
            .map(|(sig, t)| literal_of(sig, t))
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {}", self.name))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {} outputs", self.name))?;
        let parts = tuple.decompose_tuple()?;
        if parts.len() != self.outputs.len() {
            return Err(anyhow!(
                "{}: got {} outputs, manifest says {}",
                self.name,
                parts.len(),
                self.outputs.len()
            ));
        }
        self.outputs
            .iter()
            .zip(parts.iter())
            .map(|(sig, lit)| tensor_of(sig, lit))
            .collect()
    }
}

/// Engine: one PJRT CPU client + an executable cache keyed by HLO path.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Engine {
    /// Create the CPU PJRT client (the request-path runtime).
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact described by a manifest entry.
    /// Compilation happens once per path; later calls hit the cache.
    pub fn load(&self, name: &str, entry: &FnEntry) -> Result<std::sync::Arc<Executable>> {
        let key = entry.hlo_path.to_string_lossy().to_string();
        if let Some(hit) = self.cache.lock().unwrap().get(&key) {
            return Ok(hit.clone());
        }
        let exe = self.compile_file(name, &entry.hlo_path, &entry.inputs, &entry.outputs)?;
        let arc = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(key, arc.clone());
        Ok(arc)
    }

    /// Compile an HLO text file with an explicit signature.
    pub fn compile_file(
        &self,
        name: &str,
        path: &Path,
        inputs: &[TensorSig],
        outputs: &[TensorSig],
    ) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable {
            name: name.to_string(),
            inputs: inputs.to_vec(),
            outputs: outputs.to_vec(),
            exe,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use std::path::PathBuf;

    fn manifest() -> Option<Manifest> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&d).ok()
    }

    #[test]
    fn clp_roundtrip_kernel_matches_rust_clp() {
        // The AOT'd Pallas CLP kernel must agree with noc::clp bit-for-bit.
        let Some(man) = manifest() else { return };
        let engine = Engine::cpu().unwrap();
        let entry = man.kernel("clp_roundtrip").unwrap();
        let exe = engine.load("clp_roundtrip", entry).unwrap();
        let acts: Vec<i32> = (0..256).collect();
        let out = exe.run(&[Tensor::I32(acts.clone())]).unwrap();
        let decoded = out[0].as_i32().unwrap();
        for (a, &d) in acts.iter().zip(decoded) {
            let expect = crate::noc::clp::decode(
                crate::noc::clp::spike_count(*a as u32, 8, 8),
                8,
                8,
            );
            assert_eq!(d as u32, expect, "a={a}");
        }
    }

    #[test]
    fn rate_encode_kernel_matches_rust_clp() {
        let Some(man) = manifest() else { return };
        let engine = Engine::cpu().unwrap();
        let exe = engine.load("rate_encode", man.kernel("rate_encode").unwrap()).unwrap();
        let acts: Vec<i32> = (0..256).collect();
        let out = exe.run(&[Tensor::I32(acts.clone())]).unwrap();
        let spikes = out[0].as_i32().unwrap(); // [8, 256] time-major
        for (i, &a) in acts.iter().enumerate() {
            let count: i32 = (0..8).map(|t| spikes[t * 256 + i]).sum();
            assert_eq!(count as u32, crate::noc::clp::spike_count(a as u32, 8, 8));
        }
    }

    #[test]
    fn spike_matmul_kernel_runs() {
        let Some(man) = manifest() else { return };
        let engine = Engine::cpu().unwrap();
        let exe = engine.load("spike_matmul", man.kernel("spike_matmul").unwrap()).unwrap();
        // all-ones spikes x identity-ish weights
        let spikes = vec![1.0f32; 16 * 256];
        let mut w = vec![0.0f32; 256 * 256];
        for i in 0..256 {
            w[i * 256 + i] = 2.0;
        }
        let out = exe.run(&[Tensor::F32(spikes), Tensor::F32(w)]).unwrap();
        let y = out[0].as_f32().unwrap();
        assert_eq!(y.len(), 16 * 256);
        assert!(y.iter().all(|&v| (v - 2.0).abs() < 1e-6));
    }

    #[test]
    fn executable_cache_hits() {
        let Some(man) = manifest() else { return };
        let engine = Engine::cpu().unwrap();
        let e1 = engine.load("clp_roundtrip", man.kernel("clp_roundtrip").unwrap()).unwrap();
        let e2 = engine.load("clp_roundtrip", man.kernel("clp_roundtrip").unwrap()).unwrap();
        assert!(std::sync::Arc::ptr_eq(&e1, &e2));
    }

    #[test]
    fn arg_count_mismatch_is_error() {
        let Some(man) = manifest() else { return };
        let engine = Engine::cpu().unwrap();
        let exe = engine.load("clp_roundtrip", man.kernel("clp_roundtrip").unwrap()).unwrap();
        assert!(exe.run(&[]).is_err());
    }
}
