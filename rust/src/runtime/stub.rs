//! No-XLA stand-in for the PJRT engine, compiled when the `xla` feature is
//! off (the default). Keeps the full `Engine`/`Executable` API surface so
//! every dependent (training driver, CLI, examples, benches, tests)
//! compiles unchanged; construction fails at runtime with a clear message,
//! which the artifact-gated tests already treat as "skip".

use std::path::Path;

use anyhow::{anyhow, Result};

use super::manifest::{FnEntry, TensorSig};
use super::tensor::Tensor;

fn unavailable() -> anyhow::Error {
    anyhow!(
        "spikelink was built without the `xla` feature: the PJRT runtime is stubbed out. \
         Rebuild with `cargo build --features xla` (requires the xla_extension bindings) \
         to execute AOT artifacts"
    )
}

/// A compiled computation with its I/O signature (stub: cannot exist).
pub struct Executable {
    pub name: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

impl Executable {
    /// Run with host tensors — always an error in a stub build.
    pub fn run(&self, _args: &[Tensor]) -> Result<Vec<Tensor>> {
        Err(unavailable())
    }
}

/// Engine stub: `cpu()` fails, so no `Executable` is ever constructed.
pub struct Engine {
    _private: (),
}

impl Engine {
    /// Always errors in a stub build (callers treat it as "runtime absent").
    pub fn cpu() -> Result<Engine> {
        Err(unavailable())
    }

    pub fn platform(&self) -> String {
        "stub (built without the xla feature)".to_string()
    }

    pub fn load(&self, _name: &str, _entry: &FnEntry) -> Result<std::sync::Arc<Executable>> {
        Err(unavailable())
    }

    pub fn compile_file(
        &self,
        _name: &str,
        _path: &Path,
        _inputs: &[TensorSig],
        _outputs: &[TensorSig],
    ) -> Result<Executable> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_engine_reports_missing_feature() {
        let err = Engine::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("xla"), "unhelpful error: {err}");
    }
}
