//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path.
//! Python never runs here — `make artifacts` is the only python step.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, Executable, Tensor};
pub use manifest::{DType, FnEntry, Manifest, ModelEntry, TensorSig};
