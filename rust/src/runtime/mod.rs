//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path.
//! Python never runs here — `make artifacts` is the only python step.
//!
//! The real engine needs the external `xla` (PJRT) bindings, so it sits
//! behind the default-off `xla` cargo feature; without it a stub with the
//! identical API surface is compiled instead ([`Engine::cpu`] errors, the
//! artifact-gated tests skip). [`Tensor`] and the manifest are pure host
//! code and always available.

pub mod manifest;
pub mod tensor;

#[cfg(feature = "xla")]
pub mod engine;

#[cfg(not(feature = "xla"))]
#[path = "stub.rs"]
pub mod engine;

pub use engine::{Engine, Executable};
pub use manifest::{DType, FnEntry, Manifest, ModelEntry, TensorSig};
pub use tensor::Tensor;
