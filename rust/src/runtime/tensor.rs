//! Host-side tensors exchanged with an executable. Pure host code — shared
//! by the real PJRT engine (`--features xla`) and the default stub, so the
//! training driver and tests compile identically under both builds.

use anyhow::{anyhow, Result};

/// A host-side tensor exchanged with an executable.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v) => v.len(),
            Tensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    /// First element as f64 (scalar outputs: loss, metric...).
    pub fn scalar(&self) -> Result<f64> {
        match self {
            Tensor::F32(v) => v.first().map(|&x| x as f64).ok_or_else(|| anyhow!("empty")),
            Tensor::I32(v) => v.first().map(|&x| x as f64).ok_or_else(|| anyhow!("empty")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors_enforce_dtype() {
        let f = Tensor::F32(vec![1.0, 2.0]);
        assert_eq!(f.len(), 2);
        assert!(f.as_f32().is_ok());
        assert!(f.as_i32().is_err());
        let i = Tensor::I32(vec![3]);
        assert_eq!(i.scalar().unwrap(), 3.0);
        assert!(Tensor::F32(vec![]).scalar().is_err());
        assert!(Tensor::F32(vec![]).is_empty());
    }
}
