//! Drain-feasibility analysis over the channel-dependency graph.
//!
//! The communication graph of every topology reduces, for die-to-die
//! purposes, to its ordered boundary edges: a mesh has none, a duplex one,
//! a `chips`-chip chain `chips - 1`. Every transfer in the injection
//! schedule crosses the contiguous edge range `[src_chip, dest_chip)`
//! (chain traffic is eastward by construction), so the per-edge load is
//! computable without running an engine.
//!
//! Two facts about the EMIO model (see [`crate::noc::emio`]) give a sound
//! *lower* bound on drain cycles — the Eq. 8 serialization bound:
//!
//! * a lane serializes one 38-bit frame per [`SER_CYCLES`]; `p` frames
//!   spread over the [`LANES`] lanes cannot all finish serializing before
//!   `ceil(p / LANES) * SER_CYCLES` cycles after the first injection, and
//!   the last frame still pays the [`DES_CYCLES`] pipeline;
//! * the pad transmits at most one frame per cycle and transmits nothing
//!   during a link-down window (plus [`CREDIT_RECOVERY_CYCLES`]), so
//!   blocked windows push the last transmission out by their overlap.
//!
//! Retry inflation: with a bit-error rate `b` and a retry budget `R`, each
//! frame is expected to be re-sent `b + b^2 + … + b^R` times, and every
//! retry re-pays full serialization. The floor charges the *expected*
//! inflation (documented in EXPERIMENTS.md §Check); the suggested bound
//! charges the worst case (`R` retries for every frame) plus worst-case
//! lane skew, so a run at the suggestion drains.
//!
//! A window that blocks the pad through the whole drain horizon
//! (`t_last + max_cycles`) while more frames must cross than fit before it
//! is a **dead edge**: the run is guaranteed [`TimedOut`] no matter what
//! the engine does — exactly the case the service should reject without
//! burning an engine slot.
//!
//! [`TimedOut`]: crate::noc::DrainOutcome::TimedOut

use crate::codec::CodecId;
use crate::noc::emio::{DES_CYCLES, LANES, SER_CYCLES};
use crate::noc::faults::{FaultPlan, CREDIT_RECOVERY_CYCLES};
use crate::noc::scenario::{Scenario, TrafficSpec};

/// Traffic attributed to one boundary edge by the static schedule walk.
#[derive(Debug, Clone)]
pub struct EdgeLoad {
    /// Boundary index (link between chip `edge` and chip `edge + 1`).
    pub edge: usize,
    /// Frames that must cross this edge (one frame per crossing packet).
    pub packets: u64,
    /// Earliest injection cycle among the crossing transfers.
    pub first_inject: u64,
}

/// A statically-proven permanent outage: this edge's run is guaranteed to
/// time out.
#[derive(Debug, Clone)]
pub struct DeadEdge {
    pub edge: usize,
    /// Crossing frames stranded behind the window.
    pub packets: u64,
    /// The blocking window, as written in the fault plan.
    pub from: u64,
    pub until: u64,
}

/// Result of the static drain-feasibility pass.
#[derive(Debug, Clone, Default)]
pub struct DrainAnalysis {
    /// Last injection cycle in the schedule (drain starts after it).
    pub t_last: u64,
    /// Per-edge loads, trafficked edges only, ascending by edge.
    pub loads: Vec<EdgeLoad>,
    /// Edges proven permanently blocked under their traffic.
    pub dead: Vec<DeadEdge>,
    /// Eq. 8 lower bound on post-injection drain cycles (0 when no edge
    /// carries traffic). Meaningless when `dead` is non-empty.
    pub floor: u64,
    /// A sound `max_cycles` suggestion: worst-case serialization, retries,
    /// blocked windows, jitter, and generous mesh slack.
    pub suggested: u64,
}

/// Expected extra transmissions for `packets` frames at bit-error rate
/// `ber` under a budget of `max_retries` re-sends per frame.
fn expected_retry_extra(packets: u64, ber: f64, max_retries: u32) -> u64 {
    if !(ber > 0.0) || packets == 0 {
        return 0;
    }
    let b = ber.min(1.0);
    let mut geom = 0.0;
    let mut term = 1.0;
    for _ in 0..max_retries {
        term *= b;
        geom += term;
    }
    saturating_cycles(packets_f64(packets) * geom)
}

/// `u64 -> f64` for cycle arithmetic; counts this large have no exact
/// representation anyway and only feed bounds.
#[allow(clippy::cast_precision_loss)]
fn packets_f64(n: u64) -> f64 {
    n as f64
}

/// `f64 -> u64` cycle count, clamped at zero and saturated at the top —
/// the only place the analysis leaves integer arithmetic.
#[allow(clippy::cast_possible_truncation, clippy::cast_sign_loss)]
fn saturating_cycles(x: f64) -> u64 {
    if x <= 0.0 {
        0
    } else if x >= packets_f64(u64::MAX) {
        u64::MAX
    } else {
        x.floor() as u64
    }
}

/// End of a link-down window as the pad sees it: the outage plus credit
/// recovery, saturating (a `u64::MAX` window stays permanent).
fn window_end(until: u64) -> u64 {
    until.saturating_add(CREDIT_RECOVERY_CYCLES)
}

/// Absolute cycle of the last pad transmission, as a lower bound: the pad
/// sends one frame per non-blocked cycle starting at `start`, skipping the
/// `(from, end)` windows (pre-sorted by `from`).
fn pad_finish(start: u64, frames: u64, windows: &[(u64, u64)]) -> u64 {
    debug_assert!(frames > 0);
    let mut t = start;
    let mut left = frames;
    for &(from, end) in windows {
        if end <= t {
            continue;
        }
        let avail = from.saturating_sub(t);
        if avail >= left {
            return t + (left - 1);
        }
        left -= avail;
        t = end;
    }
    t + (left - 1)
}

/// The codec carried by boundary edge `e`, when the traffic is
/// codec-shaped (needed for the temporal decode-latency overhead).
fn edge_codec(traffic: &TrafficSpec, e: usize) -> Option<(CodecId, u32)> {
    match traffic {
        TrafficSpec::Boundary { ticks, codec, codecs, .. } => {
            Some((codecs.get(&e).copied().unwrap_or(*codec), *ticks))
        }
        _ => None,
    }
}

/// Run the full static drain-feasibility pass for `sc`.
pub fn analyze(sc: &Scenario) -> DrainAnalysis {
    let sched = sc.schedule();
    let n_edges = sc.topology.chips().saturating_sub(1);
    let mut out = DrainAnalysis::default();
    if sched.is_empty() {
        return out;
    }
    out.t_last = sched.iter().map(|&(c, _)| c).max().unwrap_or(0);
    let total_transfers = sched.len() as u64;

    // Attribute every transfer to the contiguous edge range it crosses.
    let mut packets = vec![0u64; n_edges];
    let mut first = vec![u64::MAX; n_edges];
    for &(cycle, ref t) in &sched {
        for e in t.src_chip..t.dest_chip.min(n_edges) {
            packets[e] += 1;
            first[e] = first[e].min(cycle);
        }
    }
    out.loads = (0..n_edges)
        .filter(|&e| packets[e] > 0)
        .map(|e| EdgeLoad { edge: e, packets: packets[e], first_inject: first[e] })
        .collect();

    let lanes = LANES as u64;
    let horizon = out.t_last.saturating_add(sc.max_cycles);
    let mut floor_abs = 0u64;
    // Suggested drain budget, accumulated per edge then padded with slack
    // for intra-chip mesh routing + ejection.
    let mut suggest = 0u64;

    for load in &out.loads {
        let e = load.edge;
        let p = load.packets;
        let plan = sc.faults.as_ref();
        let (ber, jitter, retries) = plan
            .map(|f| {
                (
                    f.bers.get(&e).copied().unwrap_or(f.ber),
                    f.jitters.get(&e).copied().unwrap_or(f.jitter),
                    f.max_retries,
                )
            })
            .unwrap_or((0.0, 0, 0));
        let mut windows: Vec<(u64, u64)> = plan
            .map(|f| {
                f.link_down
                    .iter()
                    .filter(|w| w.edge == e)
                    .map(|w| (w.from, window_end(w.until)))
                    .collect()
            })
            .unwrap_or_default();
        windows.sort_unstable();

        // Permanent outage: blocked through the whole drain horizon with
        // more frames to cross than fit before the window opens.
        if let Some(w) = plan.into_iter().flat_map(|f| &f.link_down).find(|w| {
            w.edge == e && window_end(w.until) >= horizon && p > w.from.saturating_sub(SER_CYCLES)
        }) {
            out.dead.push(DeadEdge { edge: e, packets: p, from: w.from, until: w.until });
            continue;
        }

        let eff = p + expected_retry_extra(p, ber, retries);
        let overhead = edge_codec(&sc.traffic, e)
            .map(|(c, ticks)| c.codec().latency_overhead_cycles(ticks))
            .unwrap_or(0);
        let ser_complete = load
            .first_inject
            .saturating_add(eff.div_ceil(lanes).saturating_mul(SER_CYCLES))
            .saturating_add(DES_CYCLES);
        let pad_complete = pad_finish(load.first_inject.saturating_add(SER_CYCLES), eff, &windows)
            .saturating_add(DES_CYCLES);
        floor_abs = floor_abs.max(ser_complete.max(pad_complete).saturating_add(overhead));

        // Worst case for the suggestion: every frame re-sent the full
        // retry budget, all frames on one lane, every blocked cycle paid.
        let worst = if ber > 0.0 { p.saturating_mul(1 + u64::from(retries)) } else { p };
        let blocked: u64 = windows
            .iter()
            .map(|&(from, end)| end.saturating_sub(from).min(1 << 32))
            .sum();
        suggest = suggest
            .saturating_add(worst.saturating_mul(SER_CYCLES + DES_CYCLES + 2))
            .saturating_add(blocked)
            .saturating_add(p.saturating_mul(jitter))
            .saturating_add(overhead);
    }

    out.floor = floor_abs.saturating_sub(out.t_last);
    // Slack for chip-local routing, stall windows, and ejection: generous
    // by design — the suggestion must let the engine drain.
    let stall_slack: u64 = sc
        .faults
        .as_ref()
        .map(|f| {
            f.stalls
                .iter()
                .map(|s| window_end(s.until).saturating_sub(s.from).min(1 << 32))
                .sum()
        })
        .unwrap_or(0);
    let dim = sc.topology.dim() as u64;
    let chips = sc.topology.chips() as u64;
    out.suggested = suggest
        .saturating_add(total_transfers)
        .saturating_add(stall_slack)
        .saturating_add(8 * dim * chips)
        .saturating_add(1024);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::faults::LinkDown;
    use crate::noc::{DrainOutcome, FaultPlan, Scenario, TrafficSpec};

    fn chain_dense(chips: usize, neurons: usize, dense: usize, max_cycles: u64) -> Scenario {
        Scenario::chain(chips, 8)
            .traffic(TrafficSpec::Boundary {
                neurons,
                dense,
                activity: 0.5,
                ticks: 2,
                seed: 5,
                codec: CodecId::Dense,
                codecs: Default::default(),
                activities: Default::default(),
            })
            .with_max_cycles(max_cycles)
    }

    #[test]
    fn per_edge_loads_count_crossing_transfers() {
        // 64 neurons x 2 packets each, spanning both edges of a 3-chip chain
        let a = analyze(&chain_dense(3, 64, 2, 10_000));
        assert_eq!(a.loads.len(), 2);
        assert!(a.loads.iter().all(|l| l.packets == 128 && l.first_inject == 0));
        assert!(a.dead.is_empty());
        // Eq. 8: ceil(128/8)*38 + 38 = 646
        assert_eq!(a.floor, 16 * SER_CYCLES + DES_CYCLES);
    }

    #[test]
    fn floor_is_a_true_lower_bound_and_suggestion_drains() {
        let sc = chain_dense(3, 64, 2, 10_000);
        let a = analyze(&sc);
        let res = sc.run();
        assert_eq!(res.outcome, DrainOutcome::Drained);
        assert!(res.stats.cycles >= a.floor, "{} < {}", res.stats.cycles, a.floor);
        // a run capped at the suggestion must drain
        let res = sc.clone().with_max_cycles(a.suggested).run();
        assert_eq!(res.outcome, DrainOutcome::Drained);
    }

    #[test]
    fn permanent_window_on_a_trafficked_edge_is_dead() {
        let mut plan = FaultPlan { seed: 1, ..FaultPlan::default() };
        plan.link_down.push(LinkDown { edge: 0, from: 0, until: u64::MAX });
        let sc = chain_dense(2, 32, 1, 5_000).with_faults(plan);
        let a = analyze(&sc);
        assert_eq!(a.dead.len(), 1);
        assert_eq!(a.dead[0].edge, 0);
        assert_eq!(a.dead[0].packets, 32);
        // and the engine agrees
        assert_eq!(sc.run().outcome, DrainOutcome::TimedOut);
    }

    #[test]
    fn finite_window_is_not_dead_but_raises_the_floor() {
        let clean = analyze(&chain_dense(2, 32, 1, 100_000));
        let mut plan = FaultPlan { seed: 1, ..FaultPlan::default() };
        plan.link_down.push(LinkDown { edge: 0, from: 0, until: 2_000 });
        let sc = chain_dense(2, 32, 1, 100_000).with_faults(plan);
        let a = analyze(&sc);
        assert!(a.dead.is_empty());
        assert!(a.floor > clean.floor, "{} <= {}", a.floor, clean.floor);
        assert_eq!(sc.run().outcome, DrainOutcome::Drained);
    }

    #[test]
    fn retry_inflation_raises_the_floor() {
        let clean = analyze(&chain_dense(2, 64, 2, 100_000));
        let sc = chain_dense(2, 64, 2, 100_000).with_faults(FaultPlan::with_ber(3, 0.5));
        let a = analyze(&sc);
        assert!(a.floor > clean.floor, "{} <= {}", a.floor, clean.floor);
    }

    #[test]
    fn expected_retry_extra_is_the_truncated_geometric_series() {
        assert_eq!(expected_retry_extra(1000, 0.0, 3), 0);
        assert_eq!(expected_retry_extra(0, 0.5, 3), 0);
        // 1000 * (0.5 + 0.25 + 0.125) = 875
        assert_eq!(expected_retry_extra(1000, 0.5, 3), 875);
        // ber 1.0 with R retries: R extra transmissions per frame
        assert_eq!(expected_retry_extra(10, 1.0, 3), 30);
    }

    #[test]
    fn pad_finish_skips_blocked_windows() {
        // no windows: frames at start..start+4
        assert_eq!(pad_finish(100, 5, &[]), 104);
        // window covering the start pushes everything past it
        assert_eq!(pad_finish(100, 5, &[(50, 200)]), 204);
        // split: 2 frames fit before the window, 3 after
        assert_eq!(pad_finish(100, 5, &[(102, 200)]), 202);
        // already-passed window is ignored
        assert_eq!(pad_finish(100, 5, &[(10, 20)]), 104);
    }

    #[test]
    fn mesh_scenarios_have_no_edges_and_a_zero_floor() {
        let sc = Scenario::mesh(8).traffic(TrafficSpec::Uniform { packets: 64, seed: 1 });
        let a = analyze(&sc);
        assert!(a.loads.is_empty() && a.dead.is_empty());
        assert_eq!(a.floor, 0);
    }
}
