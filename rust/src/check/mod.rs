//! `spikelink check` — static analysis of scenario/profile documents.
//!
//! The repo's document dialects (`scenario/v1`, `profile/v1`, fault
//! plans) flow into three consumers — `noc-sim`, `serve`, and the learn
//! replay path — and before this module the only way to learn that a
//! document was *doomed* (a permanent link-down on a trafficked edge, a
//! `max_cycles` under the Eq. 8 serialization floor) was to run the cycle
//! engine and watch it time out. This pass proves those properties
//! ahead of time, over the parsed document and the derived
//! channel-dependency graph, and reports them as structured diagnostics
//! with stable codes (`diag/v1`) instead of ad-hoc error strings.
//!
//! ## Diagnostic codes
//!
//! | code  | severity | meaning |
//! |-------|----------|---------|
//! | CK001 | error    | body is not JSON |
//! | CK002 | error    | unrecognized document schema |
//! | CK010 | error    | document fails strict parsing (message names the field) |
//! | CK020 | error    | explicit dense codec with `dense: 0` (statically empty edge) |
//! | CK021 | error    | activity / threshold outside `[0, 1]` |
//! | CK030 | error    | permanent outage on a trafficked edge — guaranteed `TimedOut` |
//! | CK031 | warning  | `max_cycles` below the Eq. 8 drain floor (suggests a sound bound) |
//! | CK032 | warning  | fault window overlaps a hotspot burst on the same edge |
//! | CK040 | error    | learned profile edge ships more packets than uniform dense |
//! | CK041 | warning  | scenario codec edge ships more packets than uniform dense |
//!
//! Errors mean the engine run is provably wasted (or the document is
//! unreadable); warnings mean the run is legal but suspect. The CLI verb
//! exits nonzero only on errors; `serve` rejects error-bearing scenarios
//! with a 400 carrying the [`Report::to_json`] body; `noc-sim` prints the
//! report and still runs, so the engine can confirm the prediction.
//!
//! Entry points: [`check_document`] for raw text (schema-dispatched),
//! [`check_scenario`] / [`check_profile`] for parsed documents (what the
//! serve precheck and `noc-sim` use — no re-parse on the hot path).

mod drain;

pub use drain::{DeadEdge, DrainAnalysis, EdgeLoad};

use crate::codec::CodecId;
use crate::learn::LearnedProfile;
use crate::noc::emio::{DES_CYCLES, LANES, SER_CYCLES};
use crate::noc::faults::{FaultPlan, CREDIT_RECOVERY_CYCLES};
use crate::noc::scenario::{Scenario, Topology, TrafficSpec};
use crate::util::json::{self, Json};

/// Neurons-per-edge shape used when statically replaying a `profile/v1`
/// document — must match `noc-sim --profile`'s default.
pub const REPLAY_NEURONS: u64 = 64;
/// Spike-window ticks used for static profile replay — must match
/// `noc-sim --profile`'s default.
pub const REPLAY_TICKS: u32 = 8;

/// Stable diagnostic codes — the `diag/v1` contract. Codes are append-only:
/// a released code never changes meaning or severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Code {
    /// CK001: the document body is not JSON at all.
    NotJson,
    /// CK002: the document declares (or implies) no known schema.
    UnknownSchema,
    /// CK010: strict parsing rejected the document.
    InvalidDocument,
    /// CK020: explicit dense codec with `dense: 0` — a statically empty edge.
    DenseZero,
    /// CK021: an activity/threshold field outside `[0, 1]`.
    ActivityRange,
    /// CK030: permanent outage on a trafficked edge — guaranteed timeout.
    DeadEdge,
    /// CK031: `max_cycles` below the Eq. 8 drain floor.
    DrainBound,
    /// CK032: a fault window overlaps a hotspot burst on the same edge.
    FaultHotspotOverlap,
    /// CK040: a learned profile edge ships more packets than uniform dense.
    ProfileOverBudget,
    /// CK041: a scenario codec edge ships more packets than uniform dense.
    EdgeOverDense,
}

impl Code {
    /// The stable wire spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            Code::NotJson => "CK001",
            Code::UnknownSchema => "CK002",
            Code::InvalidDocument => "CK010",
            Code::DenseZero => "CK020",
            Code::ActivityRange => "CK021",
            Code::DeadEdge => "CK030",
            Code::DrainBound => "CK031",
            Code::FaultHotspotOverlap => "CK032",
            Code::ProfileOverBudget => "CK040",
            Code::EdgeOverDense => "CK041",
        }
    }

    /// Fixed severity per code — severity is part of the contract.
    pub fn severity(self) -> Severity {
        match self {
            Code::DrainBound | Code::FaultHotspotOverlap | Code::EdgeOverDense => Severity::Warning,
            _ => Severity::Error,
        }
    }
}

/// Diagnostic severity: errors make `spikelink check` exit nonzero and
/// `serve` reject the document; warnings don't.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One finding. `edge` is set when the finding is attributable to a
/// specific die boundary; `suggested_max_cycles` only on [`Code::DrainBound`].
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub code: Code,
    pub message: String,
    pub edge: Option<usize>,
    pub suggested_max_cycles: Option<u64>,
}

impl Diagnostic {
    fn new(code: Code, message: String) -> Self {
        Diagnostic { code, message, edge: None, suggested_max_cycles: None }
    }

    fn on_edge(code: Code, edge: usize, message: String) -> Self {
        Diagnostic { code, message, edge: Some(edge), suggested_max_cycles: None }
    }

    pub fn severity(&self) -> Severity {
        self.code.severity()
    }
}

/// Which dialect the checked document turned out to be.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DocKind {
    Scenario,
    Profile,
    Unknown,
}

impl DocKind {
    pub fn as_str(self) -> &'static str {
        match self {
            DocKind::Scenario => "scenario",
            DocKind::Profile => "profile",
            DocKind::Unknown => "unknown",
        }
    }
}

/// The result of one check pass: every diagnostic, in emission order
/// (graph findings after document findings).
#[derive(Debug, Clone)]
pub struct Report {
    pub kind: DocKind,
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    fn new(kind: DocKind) -> Self {
        Report { kind, diagnostics: Vec::new() }
    }

    /// True when the document produced no diagnostics at all.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    pub fn error_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity() == Severity::Error).count()
    }

    pub fn warning_count(&self) -> usize {
        self.diagnostics.len() - self.error_count()
    }

    /// True when any diagnostic is an error — the reject/exit-nonzero bit.
    pub fn has_errors(&self) -> bool {
        self.error_count() > 0
    }

    /// Boundary edges proven permanently dead ([`Code::DeadEdge`]),
    /// ascending — what `noc-sim` names in its stranded-packet warning.
    pub fn dead_edges(&self) -> Vec<usize> {
        let mut edges: Vec<usize> = self
            .diagnostics
            .iter()
            .filter(|d| d.code == Code::DeadEdge)
            .filter_map(|d| d.edge)
            .collect();
        edges.sort_unstable();
        edges.dedup();
        edges
    }

    /// The `diag/v1` JSON body (what `serve` returns with a 400).
    pub fn to_json(&self) -> Json {
        let diags: Vec<Json> = self
            .diagnostics
            .iter()
            .map(|d| {
                Json::obj(vec![
                    ("code", Json::str(d.code.as_str())),
                    ("severity", Json::str(d.severity().as_str())),
                    ("message", Json::str(d.message.clone())),
                    ("edge", d.edge.map_or(Json::Null, |e| Json::num(e as f64))),
                    (
                        "suggested_max_cycles",
                        d.suggested_max_cycles.map_or(Json::Null, |c| Json::num(cycles_f64(c))),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::str("diag/v1")),
            ("document", Json::str(self.kind.as_str())),
            ("errors", Json::num(self.error_count() as f64)),
            ("warnings", Json::num(self.warning_count() as f64)),
            ("diagnostics", Json::Arr(diags)),
        ])
    }

    /// Human rendering, one line per diagnostic plus a verdict line, every
    /// line prefixed with `source` (a path or label).
    pub fn render(&self, source: &str) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&format!(
                "{source}: {}[{}]: {}\n",
                d.severity().as_str(),
                d.code.as_str(),
                d.message
            ));
        }
        if self.is_clean() {
            out.push_str(&format!("{source}: ok — no diagnostics ({})\n", self.kind.as_str()));
        } else {
            out.push_str(&format!(
                "{source}: {} error(s), {} warning(s)\n",
                self.error_count(),
                self.warning_count()
            ));
        }
        out
    }
}

/// `u64 -> f64` for the JSON layer; suggested bounds far beyond 2^53 don't
/// survive JSON anyway and only lose precision, not magnitude.
#[allow(clippy::cast_precision_loss)]
fn cycles_f64(c: u64) -> f64 {
    c as f64
}

// -- document entry point ---------------------------------------------------

/// Check a raw document: parse as JSON, dispatch on schema, run the
/// dialect's probes + strict parse + semantic pass. Never fails — every
/// problem becomes a diagnostic.
pub fn check_document(text: &str) -> Report {
    let j = match json::parse(text) {
        Ok(j) => j,
        Err(e) => {
            let mut r = Report::new(DocKind::Unknown);
            r.diagnostics
                .push(Diagnostic::new(Code::NotJson, format!("document is not JSON: {e}")));
            return r;
        }
    };
    let schema = j.get("schema").and_then(Json::as_str);
    match schema {
        Some("scenario/v1") => check_scenario_text(text, &j),
        Some("profile/v1") => check_profile_text(text, &j),
        Some(other) => {
            let mut r = Report::new(DocKind::Unknown);
            r.diagnostics.push(Diagnostic::new(
                Code::UnknownSchema,
                format!("unknown document schema {other:?} (expected scenario/v1 or profile/v1)"),
            ));
            r
        }
        // scenario/v1 allows an absent schema key; infer the dialect from
        // its required top-level shape
        None if j.get("topology").is_some() => check_scenario_text(text, &j),
        None if j.get("edges").is_some() && j.get("model").is_some() => {
            check_profile_text(text, &j)
        }
        None => {
            let mut r = Report::new(DocKind::Unknown);
            r.diagnostics.push(Diagnostic::new(
                Code::UnknownSchema,
                "document has no schema key and matches no known dialect".to_string(),
            ));
            r
        }
    }
}

fn check_scenario_text(text: &str, j: &Json) -> Report {
    let mut r = Report::new(DocKind::Scenario);
    r.diagnostics.extend(scenario_probes(j));
    match Scenario::from_json_str(text) {
        Ok(sc) => r.diagnostics.extend(check_scenario(&sc).diagnostics),
        Err(e) => {
            // the targeted probes explain the rejection better than the
            // parser string; fall back to CK010 only when none fired
            if r.diagnostics.is_empty() {
                let msg = format!("invalid scenario: {e:#}");
                r.diagnostics.push(Diagnostic::new(Code::InvalidDocument, msg));
            }
        }
    }
    r
}

fn check_profile_text(text: &str, j: &Json) -> Report {
    let mut r = Report::new(DocKind::Profile);
    r.diagnostics.extend(profile_probes(j));
    match LearnedProfile::from_json_str(text) {
        Ok(p) => r.diagnostics.extend(check_profile(&p, REPLAY_NEURONS, REPLAY_TICKS).diagnostics),
        Err(e) => {
            if r.diagnostics.is_empty() {
                let msg = format!("invalid profile: {e:#}");
                r.diagnostics.push(Diagnostic::new(Code::InvalidDocument, msg));
            }
        }
    }
    r
}

// -- JSON-level probes (stable codes for parse-fatal document classes) ------

fn range_ok(a: f64) -> bool {
    (0.0..=1.0).contains(&a)
}

/// Probe the raw scenario JSON for the known-bad codec shapes that the
/// strict parser rejects with ad-hoc strings: an explicit dense codec on a
/// zero-width edge (CK020) and out-of-range activities (CK021).
fn scenario_probes(j: &Json) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let Some(tr) = j.get("traffic") else { return out };
    if tr.get("kind").and_then(Json::as_str) != Some("boundary") {
        return out;
    }
    let dense_zero = tr.get("dense").and_then(Json::as_f64) == Some(0.0);
    if dense_zero && tr.get("codec").and_then(Json::as_str) == Some("dense") {
        out.push(Diagnostic::new(
            Code::DenseZero,
            "explicit dense codec with dense: 0 — a zero-width dense edge is empty, the \
             document describes traffic that cannot exist"
                .to_string(),
        ));
    }
    if let Some(a) = tr.get("activity").and_then(Json::as_f64) {
        if !range_ok(a) {
            out.push(Diagnostic::new(
                Code::ActivityRange,
                format!("traffic.activity must be in [0, 1], got {a}"),
            ));
        }
    }
    if let Some(Json::Obj(map)) = tr.get("codecs") {
        for (key, val) in map {
            let edge = key.parse::<usize>().ok();
            let name = val.as_str().or_else(|| val.get("codec").and_then(Json::as_str));
            if dense_zero && name == Some("dense") {
                let mut d = Diagnostic::new(
                    Code::DenseZero,
                    format!("codecs[{key}] selects the dense codec while dense: 0 — a \
                             zero-width dense edge is empty"),
                );
                d.edge = edge;
                out.push(d);
            }
            if let Some(a) = val.get("activity").and_then(Json::as_f64) {
                if !range_ok(a) {
                    let mut d = Diagnostic::new(
                        Code::ActivityRange,
                        format!("codecs[{key}].activity must be in [0, 1], got {a}"),
                    );
                    d.edge = edge;
                    out.push(d);
                }
            }
        }
    }
    out
}

/// Probe the raw profile JSON for out-of-range rates (CK021).
fn profile_probes(j: &Json) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if let Some(b) = j.get("rate_budget").and_then(Json::as_f64) {
        if !range_ok(b) {
            out.push(Diagnostic::new(
                Code::ActivityRange,
                format!("rate_budget must be in [0, 1], got {b}"),
            ));
        }
    }
    let Some(Json::Arr(edges)) = j.get("edges") else { return out };
    for (i, e) in edges.iter().enumerate() {
        let edge = e.get("edge").and_then(Json::as_usize).or(Some(i));
        for field in ["activity", "threshold"] {
            if let Some(a) = e.get(field).and_then(Json::as_f64) {
                if !range_ok(a) {
                    let mut d = Diagnostic::new(
                        Code::ActivityRange,
                        format!("edges[{i}].{field} must be in [0, 1], got {a}"),
                    );
                    d.edge = edge;
                    out.push(d);
                }
            }
        }
    }
    out
}

// -- semantic pass over parsed documents ------------------------------------

/// Static analysis of a parsed scenario: dead edges, the Eq. 8 drain
/// floor, fault/hotspot overlaps, and codec admissibility. This is the
/// precheck `serve` and `noc-sim` run — it never builds an engine.
pub fn check_scenario(sc: &Scenario) -> Report {
    let mut r = Report::new(DocKind::Scenario);
    let analysis = drain::analyze(sc);

    for d in &analysis.dead {
        let until = if d.until == u64::MAX { "forever".to_string() } else { d.until.to_string() };
        r.diagnostics.push(Diagnostic::on_edge(
            Code::DeadEdge,
            d.edge,
            format!(
                "edge {}: permanent link-down window [{}, {}] blocks all {} crossing packet(s) \
                 within the drain horizon — the run is guaranteed to time out",
                d.edge, d.from, until, d.packets
            ),
        ));
    }

    if analysis.dead.is_empty() && !analysis.loads.is_empty() && sc.max_cycles < analysis.floor {
        let mut d = Diagnostic::new(
            Code::DrainBound,
            format!(
                "max_cycles {} is below the Eq. 8 drain floor of {} cycles (serialization + \
                 retry inflation over {} trafficked edge(s)); suggest --max-cycles {}",
                sc.max_cycles,
                analysis.floor,
                analysis.loads.len(),
                analysis.suggested
            ),
        );
        d.suggested_max_cycles = Some(analysis.suggested);
        r.diagnostics.push(d);
    }

    if let Some(plan) = &sc.faults {
        hotspot_overlap_probes(sc, plan, &analysis, &mut r.diagnostics);
    }

    codec_admissibility_probes(sc, &mut r.diagnostics);
    r
}

/// CK032: a link-down window and a hotspot burst touching the same edge at
/// overlapping times — the burst's frames pile up behind the blocked pad.
fn hotspot_overlap_probes(
    sc: &Scenario,
    plan: &FaultPlan,
    analysis: &DrainAnalysis,
    out: &mut Vec<Diagnostic>,
) {
    let dead: Vec<usize> = analysis.dead.iter().map(|d| d.edge).collect();
    for h in &plan.hotspots {
        // a burst converging on chip `c` can cross every edge west of it
        let edges_end = match sc.topology {
            Topology::Mesh { .. } => 0,
            Topology::Duplex { .. } | Topology::Chain { .. } => h.chip,
        };
        let frames = h.packets as u64;
        let burst_end = h
            .at
            .saturating_add(frames.div_ceil(LANES as u64).saturating_mul(SER_CYCLES))
            .saturating_add(DES_CYCLES);
        for w in &plan.link_down {
            let blocked_end = w.until.saturating_add(CREDIT_RECOVERY_CYCLES);
            if w.edge < edges_end
                && !dead.contains(&w.edge)
                && w.from <= burst_end
                && h.at < blocked_end
            {
                out.push(Diagnostic::on_edge(
                    Code::FaultHotspotOverlap,
                    w.edge,
                    format!(
                        "link-down window [{}, {}] on edge {} overlaps the {}-packet hotspot \
                         burst at cycle {} targeting chip {} — the burst serializes into a \
                         blocked pad",
                        w.from, w.until, w.edge, h.packets, h.at, h.chip
                    ),
                ));
            }
        }
    }
}

/// CK041: a codec-shaped boundary edge that statically ships more packets
/// than the uniform-dense encoding of the same edge — legal, but it
/// defeats the sparsification the codec exists for.
fn codec_admissibility_probes(sc: &Scenario, out: &mut Vec<Diagnostic>) {
    let TrafficSpec::Boundary { neurons, dense, activity, ticks, codec, codecs, activities, .. } =
        &sc.traffic
    else {
        return;
    };
    let n = *neurons as u64;
    let bits = u32::try_from(*dense).unwrap_or(u32::MAX).saturating_mul(8);
    // the dense budget: `dense` packets per neuron, at least one (the
    // profile replay baseline uses dense: 1)
    let budget = n.saturating_mul((*dense as u64).max(1));
    let n_edges = sc.topology.chips().saturating_sub(1);
    if codecs.is_empty() {
        let packets = codec.codec().packets_per_edge(n, *activity, *ticks, bits);
        if packets > budget {
            out.push(Diagnostic::new(
                Code::EdgeOverDense,
                format!(
                    "{} codec at activity {} ships {} packets per edge — more than the {} of \
                     uniform dense",
                    codec.as_str(),
                    activity,
                    packets,
                    budget
                ),
            ));
        }
        return;
    }
    for e in 0..n_edges {
        let c = codecs.get(&e).copied().unwrap_or(*codec);
        let a = activities.get(&e).copied().unwrap_or(*activity);
        let packets = c.codec().packets_per_edge(n, a, *ticks, bits);
        if packets > budget {
            out.push(Diagnostic::on_edge(
                Code::EdgeOverDense,
                e,
                format!(
                    "edge {e}: {} codec at activity {a} ships {packets} packets — more than \
                     the {budget} of uniform dense",
                    c.as_str()
                ),
            ));
        }
    }
}

/// Static admissibility of a learned profile at the replay shape
/// (`neurons` per edge, `ticks` spike window — `noc-sim --profile`'s
/// defaults unless overridden): every edge must ship at most the
/// uniform-dense packet count, the invariant the replay path errors on.
pub fn check_profile(p: &LearnedProfile, neurons: u64, ticks: u32) -> Report {
    let mut r = Report::new(DocKind::Profile);
    // replay baseline: dense: 1 → 8-bit payloads, `neurons` packets/edge
    let budget = neurons;
    for ep in &p.edges {
        let packets = ep.codec.codec().packets_per_edge(neurons, ep.activity, ticks, 8);
        if packets > budget {
            r.diagnostics.push(Diagnostic::on_edge(
                Code::ProfileOverBudget,
                ep.edge,
                format!(
                    "edge {}: learned {} codec at activity {} ships {} packets at the replay \
                     shape (neurons {neurons}, ticks {ticks}) — exceeds the uniform-dense \
                     budget of {budget}",
                    ep.edge,
                    ep.codec.as_str(),
                    ep.activity,
                    packets
                ),
            ));
        }
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    const VALID_CHAIN: &str = r#"{"schema":"scenario/v1",
        "topology":{"kind":"chain","chips":3,"dim":4},
        "traffic":{"kind":"boundary","neurons":64,"dense":0,"activity":0.25,
                   "ticks":2,"seed":9,"codec":"rate"},"telemetry":true}"#;

    fn codes(r: &Report) -> Vec<&'static str> {
        r.diagnostics.iter().map(|d| d.code.as_str()).collect()
    }

    #[test]
    fn valid_documents_are_clean() {
        let r = check_document(VALID_CHAIN);
        assert!(r.is_clean(), "{:?}", r.diagnostics);
        assert_eq!(r.kind, DocKind::Scenario);
        let profile = r#"{"schema":"profile/v1","seed":7,"lam":0.5,"rate_budget":0.1,
            "model":"ms-resnet18",
            "edges":[{"edge":0,"codec":"topk-delta","activity":0.08,"threshold":0.42}]}"#;
        let r = check_document(profile);
        assert!(r.is_clean(), "{:?}", r.diagnostics);
        assert_eq!(r.kind, DocKind::Profile);
    }

    #[test]
    fn non_json_and_unknown_schema_get_their_codes() {
        assert_eq!(codes(&check_document("{nope")), ["CK001"]);
        assert_eq!(codes(&check_document(r#"{"schema":"walrus/v9"}"#)), ["CK002"]);
        assert_eq!(codes(&check_document(r#"{"surprise":1}"#)), ["CK002"]);
    }

    #[test]
    fn parse_failures_fall_back_to_ck010() {
        let doc = r#"{"schema":"scenario/v1","topology":{"kind":"mesh","dim":4},
            "traffic":{"kind":"uniform","packets":4,"seed":1},"bogus_key":1}"#;
        let r = check_document(doc);
        assert_eq!(codes(&r), ["CK010"]);
        assert!(r.diagnostics[0].message.contains("bogus_key"));
        assert!(r.has_errors());
    }

    #[test]
    fn dense_zero_probe_beats_the_parser_string() {
        let doc = r#"{"schema":"scenario/v1","topology":{"kind":"duplex","dim":4},
            "traffic":{"kind":"boundary","neurons":64,"dense":0,"activity":0.3,
                       "ticks":2,"seed":1,"codec":"dense"}}"#;
        let r = check_document(doc);
        assert_eq!(codes(&r), ["CK020"]);
        // per-edge spelling, object form
        let doc = r#"{"schema":"scenario/v1","topology":{"kind":"chain","chips":3,"dim":4},
            "traffic":{"kind":"boundary","neurons":64,"dense":0,"activity":0.3,"ticks":2,
                       "seed":1,"codec":"rate","codecs":{"1":"dense"}}}"#;
        let r = check_document(doc);
        assert_eq!(codes(&r), ["CK020"]);
        assert_eq!(r.diagnostics[0].edge, Some(1));
    }

    #[test]
    fn activity_range_probe_covers_scenarios_and_profiles() {
        let doc = r#"{"schema":"scenario/v1","topology":{"kind":"duplex","dim":4},
            "traffic":{"kind":"boundary","neurons":64,"dense":0,"activity":1.7,
                       "ticks":2,"seed":1,"codec":"rate"}}"#;
        assert_eq!(codes(&check_document(doc)), ["CK021"]);
        let doc = r#"{"schema":"profile/v1","seed":7,"lam":0.5,"rate_budget":0.1,
            "model":"m","edges":[{"edge":0,"codec":"rate","activity":-0.5,"threshold":0.4}]}"#;
        let r = check_document(doc);
        assert_eq!(codes(&r), ["CK021"]);
        assert_eq!(r.diagnostics[0].edge, Some(0));
    }

    #[test]
    fn dead_edge_is_an_error_and_names_the_edge() {
        let doc = r#"{"schema":"scenario/v1","topology":{"kind":"duplex","dim":4},
            "traffic":{"kind":"full-span","packets":32,"seed":7},"max_cycles":5000,
            "faults":{"seed":1,"link_down":[{"edge":0,"from":0,"until":999999999999}]}}"#;
        let r = check_document(doc);
        assert_eq!(codes(&r), ["CK030"]);
        assert!(r.has_errors());
        assert_eq!(r.dead_edges(), [0]);
    }

    #[test]
    fn low_max_cycles_is_a_warning_with_a_suggestion() {
        let doc = r#"{"schema":"scenario/v1","topology":{"kind":"chain","chips":3,"dim":8},
            "traffic":{"kind":"boundary","neurons":256,"dense":2,"activity":0.5,
                       "ticks":2,"seed":5,"codec":"dense"},"max_cycles":200}"#;
        let r = check_document(doc);
        assert_eq!(codes(&r), ["CK031"]);
        assert!(!r.has_errors(), "a drain-bound warning must not fail the check");
        let s = r.diagnostics[0].suggested_max_cycles.expect("suggestion");
        assert!(s > 200);
    }

    #[test]
    fn profile_over_budget_is_an_error_at_the_replay_shape() {
        let doc = r#"{"schema":"profile/v1","seed":7,"lam":0.5,"rate_budget":0.1,
            "model":"m","edges":[{"edge":0,"codec":"rate","activity":0.9,"threshold":0.1}]}"#;
        let r = check_document(doc);
        assert_eq!(codes(&r), ["CK040"]);
        assert!(r.has_errors());
    }

    #[test]
    fn diag_v1_json_shape_is_stable() {
        let doc = r#"{"schema":"scenario/v1","topology":{"kind":"duplex","dim":4},
            "traffic":{"kind":"full-span","packets":32,"seed":7},"max_cycles":5000,
            "faults":{"seed":1,"link_down":[{"edge":0,"from":0,"until":999999999999}]}}"#;
        let j = check_document(doc).to_json();
        assert_eq!(j.get("schema").unwrap().as_str(), Some("diag/v1"));
        assert_eq!(j.get("document").unwrap().as_str(), Some("scenario"));
        assert_eq!(j.get("errors").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("warnings").unwrap().as_f64(), Some(0.0));
        let arr = j.get("diagnostics").and_then(Json::as_arr).unwrap();
        assert_eq!(arr[0].get("code").unwrap().as_str(), Some("CK030"));
        assert_eq!(arr[0].get("severity").unwrap().as_str(), Some("error"));
        assert_eq!(arr[0].get("edge").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn human_rendering_names_the_source_and_verdict() {
        let r = check_document(VALID_CHAIN);
        let text = r.render("fixture.json");
        assert!(text.contains("fixture.json: ok — no diagnostics (scenario)"));
        let r = check_document("{nope");
        let text = r.render("bad.json");
        assert!(text.contains("bad.json: error[CK001]"));
        assert!(text.contains("bad.json: 1 error(s), 0 warning(s)"));
    }
}
