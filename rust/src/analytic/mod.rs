//! Closed-form latency + energy engine — the evaluation pipeline of §4
//! (Fig. 6 workflow): map a network onto an ANN/SNN/HNN chip array,
//! partition it, count ops and packets, and evaluate Eqs. 4-9 plus the
//! ORION-scaled energy model.
//!
//! This engine produces every paper figure (10-13) and sweep (Fig. 7's
//! latency axis). The cycle-level `noc` simulator cross-validates its
//! constants (EMIO 76-cycle claim, hop counts).

pub mod energy;
pub mod latency;
pub mod workload;

use crate::arch::params::{ArchConfig, Variant};
use crate::model::layer::Network;
use crate::model::mapping::{map_network, Mapping};
use crate::model::partition::{partition, Partition};
use crate::sparsity::SparsityProfile;

pub use energy::{EnergyBreakdown, EnergyTable};
pub use latency::LatencyReport;
pub use workload::LayerWork;

/// Full simulation result for one (network, arch, sparsity) triple.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub network: String,
    pub variant: Variant,
    pub cfg: ArchConfig,
    pub works: Vec<LayerWork>,
    pub latency: LatencyReport,
    pub energy: EnergyBreakdown,
    pub n_chips: usize,
    pub total_cores: usize,
    /// Total packets crossing die boundaries per inference.
    pub boundary_packets: u64,
    /// Total routed packets per inference.
    pub routed_packets: u64,
    /// Total ops (MACs + ACCs).
    pub total_ops: u64,
}

impl SimReport {
    /// Inferences per second.
    pub fn throughput(&self) -> f64 {
        if self.latency.seconds > 0.0 {
            1.0 / self.latency.seconds
        } else {
            f64::INFINITY
        }
    }

    /// Energy per inference (J).
    pub fn energy_j(&self) -> f64 {
        self.energy.total_j()
    }
}

/// Run the analytic simulation.
pub fn simulate(net: &Network, cfg: &ArchConfig, profile: &SparsityProfile) -> SimReport {
    let mapping = map_network(net, cfg);
    let part = partition(net, &mapping, cfg);
    simulate_mapped(net, cfg, profile, &mapping, &part)
}

/// Variant that reuses an existing mapping/partition (for sweeps that hold
/// the placement fixed).
pub fn simulate_mapped(
    net: &Network,
    cfg: &ArchConfig,
    profile: &SparsityProfile,
    mapping: &Mapping,
    part: &Partition,
) -> SimReport {
    let works = workload::layer_workloads(net, mapping, part, cfg, profile);
    let lat = latency::latency(&works, cfg);
    let en = energy::energy(&works, cfg);
    SimReport {
        network: net.name.clone(),
        variant: cfg.variant,
        cfg: cfg.clone(),
        boundary_packets: works.iter().map(|w| w.boundary_packets).sum(),
        routed_packets: works.iter().map(|w| w.routed_packets).sum(),
        total_ops: works.iter().map(|w| w.ops).sum(),
        n_chips: mapping.n_chips,
        total_cores: mapping.total_cores,
        works,
        latency: lat,
        energy: en,
    }
}

/// Convenience: simulate all three variants of one network with the
/// paper's default sparsity assumptions (uniform `input_activity` for
/// spiking layers; ANN unaffected).
pub fn simulate_variants(net: &Network, base: &ArchConfig) -> [SimReport; 3] {
    let mk = |v: Variant| {
        let mut cfg = base.clone();
        cfg.variant = v;
        let profile = SparsityProfile::uniform(net.layers.len(), cfg.input_activity);
        simulate(net, &cfg, &profile)
    };
    [mk(Variant::Ann), mk(Variant::Snn), mk(Variant::Hnn)]
}

/// Speedup of `b` over `a` in latency (a.latency / b.latency).
pub fn speedup(a: &SimReport, b: &SimReport) -> f64 {
    a.latency.total_cycles as f64 / b.latency.total_cycles.max(1) as f64
}

/// Energy-efficiency gain of `b` over `a` (a.energy / b.energy).
pub fn efficiency_gain(a: &SimReport, b: &SimReport) -> f64 {
    a.energy_j() / b.energy_j().max(f64::MIN_POSITIVE)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::networks;

    fn base() -> ArchConfig {
        ArchConfig::baseline(Variant::Hnn)
    }

    #[test]
    fn three_variants_same_chip_demand() {
        // Mapping is variant-independent (same grouping/mesh).
        let net = networks::msresnet18();
        let [ann, snn, hnn] = simulate_variants(&net, &base());
        assert_eq!(ann.n_chips, snn.n_chips);
        assert_eq!(ann.n_chips, hnn.n_chips);
        assert!(ann.n_chips > 1, "MS-ResNet18 must span multiple chips");
    }

    #[test]
    fn hnn_faster_than_ann_on_multichip_models() {
        // §5.2: HNN achieves the fastest inference latency on static data.
        for name in ["ms-resnet18", "rwkv-6l-512"] {
            let net = networks::by_name(name).unwrap();
            let [ann, _snn, hnn] = simulate_variants(&net, &base());
            if ann.boundary_packets > 0 {
                assert!(
                    speedup(&ann, &hnn) > 1.0,
                    "{name}: ann={} hnn={}",
                    ann.latency.total_cycles,
                    hnn.latency.total_cycles
                );
            }
        }
    }

    #[test]
    fn hnn_cheaper_than_ann_in_energy() {
        // §5.3: baseline HNN is 1x-3.3x more energy efficient than ANN.
        let net = networks::msresnet18();
        let [ann, _snn, hnn] = simulate_variants(&net, &base());
        let gain = efficiency_gain(&ann, &hnn);
        assert!(gain >= 1.0, "gain={gain}");
        assert!(gain < 10.0, "gain implausibly large: {gain}");
    }

    #[test]
    fn hnn_boundary_traffic_below_ann() {
        let net = networks::msresnet18();
        let [ann, _snn, hnn] = simulate_variants(&net, &base());
        assert!(hnn.boundary_packets < ann.boundary_packets);
    }

    #[test]
    fn snn_fewest_routed_packets() {
        // all-spiking traffic at 10%x8 ticks = 0.8 packets/neuron < 1
        let net = networks::msresnet18();
        let [ann, snn, _hnn] = simulate_variants(&net, &base());
        assert!(snn.routed_packets < ann.routed_packets);
    }

    #[test]
    fn effnet_needs_most_chips() {
        // §5.3: EffNet-B4 requires far more chips than MS-ResNet18 > RWKV.
        let e = simulate(
            &networks::efficientnet_b4(),
            &base(),
            &SparsityProfile::uniform(300, 0.1),
        );
        let m = simulate(
            &networks::msresnet18(),
            &base(),
            &SparsityProfile::uniform(30, 0.1),
        );
        let r = simulate(&networks::rwkv_6l_512(), &base(), &SparsityProfile::uniform(50, 0.1));
        assert!(e.n_chips > 10 * m.n_chips, "e={} m={}", e.n_chips, m.n_chips);
        assert!(m.n_chips > r.n_chips, "m={} r={}", m.n_chips, r.n_chips);
    }

    #[test]
    fn higher_sparsity_lower_latency() {
        // Fig. 7: latency improves with sparsity.
        let net = networks::msresnet18();
        let cfg = ArchConfig::baseline(Variant::Hnn);
        let lo = simulate(&net, &cfg, &SparsityProfile::uniform(net.layers.len(), 0.3));
        let hi = simulate(&net, &cfg, &SparsityProfile::uniform(net.layers.len(), 0.02));
        assert!(hi.latency.total_cycles < lo.latency.total_cycles);
    }

    #[test]
    fn bit_width_grows_hnn_advantage() {
        // Fig. 11: speedup grows with bit precision (dense packets scale
        // with bits, spikes don't).
        let net = networks::msresnet18();
        let sp = |bits: u32| {
            let cfg = base().with_bits(bits);
            let [ann, _snn, hnn] = simulate_variants(&net, &cfg);
            speedup(&ann, &hnn)
        };
        assert!(sp(32) > sp(8), "32b={} 8b={}", sp(32), sp(8));
    }
}
