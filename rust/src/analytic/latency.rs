//! Latency model — Eqs. (6)-(9) of §4.3.
//!
//! * Eq. 6/7: per-layer compute cycles. MAC and ACC both take 1 cycle; the
//!   layer's ops spread over its parallel lanes `G x ceil(N/G)`.
//! * Eq. 8: EMIO die-to-die overhead. 38-cycle serialization batches run in
//!   parallel across the `N_c` peripheral cores feeding the pads; the
//!   38-cycle-deep deserializer is pipelined (1 packet/cycle throughput
//!   after a 38-cycle fill): single packet = 38 + 38 = 76 cycles, matching
//!   the synthesized RTL figure of §3.4.
//! * Eq. 9: total = sum of layer cycles + sum of EMIO cycles over
//!   boundary-crossing edges.

use crate::arch::params::ArchConfig;
use crate::util::stats::LatencyHist;

use super::workload::LayerWork;

/// Cycles per MAC and per ACC (§4.3: both 1).
pub const CYCLES_PER_OP: u64 = 1;
/// SerDes serialization depth in cycles for one packet (§3.4 RTL: 38).
pub const CYCLES_SER: u64 = 38;
/// Deserializer pipeline depth (fill latency) in cycles (§3.4: 38).
pub const CYCLES_DES: u64 = 38;

/// Eq. 6 / Eq. 7: compute cycles of one layer.
///
/// `ops` = MACs or ACCs; `neurons` = N; `grouping` = G. The denominator
/// `G x ceil(N/G)` is the number of parallel PE lanes the layer occupies.
pub fn compute_cycles(ops: u64, neurons: u64, grouping: usize) -> u64 {
    if ops == 0 || neurons == 0 {
        return 0;
    }
    let lanes = grouping as u64 * neurons.div_ceil(grouping as u64);
    (ops * CYCLES_PER_OP).div_ceil(lanes)
}

/// Eq. 8: EMIO cycles for `boundary_packets` crossing one die boundary with
/// `n_boundary_cores` peripheral cores serializing in parallel.
///
///   cycles = floor(P_B / N_c) x 38      (parallel serialization batches)
///          + (P_B + 38)                 (pipelined deserialization: fill
///                                        depth + 1 packet per cycle)
///
/// For a single packet this yields 38 + 39 ≈ the paper's 76-cycle figure
/// (we count the packet's own drain cycle; the RTL counts 38+38).
pub fn emio_cycles(boundary_packets: u64, n_boundary_cores: usize) -> u64 {
    if boundary_packets == 0 {
        return 0;
    }
    let nc = n_boundary_cores.max(1) as u64;
    let ser = (boundary_packets / nc) * CYCLES_SER;
    let des = boundary_packets + CYCLES_DES;
    ser + des
}

/// Single-packet die-to-die latency (the §3.4 RTL measurement): 76 cycles.
pub fn emio_single_packet_cycles() -> u64 {
    // one serialization batch + pipeline fill; the drain cycle of the lone
    // packet is folded into the fill depth per the RTL measurement.
    CYCLES_SER + CYCLES_DES
}

/// Tail-latency summary of a *measured* cycle-engine distribution — the
/// distilled form of a telemetry [`LatencyHist`] that reports and figures
/// carry around (the paper's claims are distributions, not means: §4.3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TailLatency {
    pub samples: u64,
    pub mean: f64,
    pub p50: u64,
    pub p99: u64,
    pub p999: u64,
}

impl TailLatency {
    /// Distil a streaming histogram into the three headline quantiles.
    pub fn from_hist(h: &LatencyHist) -> Self {
        TailLatency {
            samples: h.count(),
            mean: h.mean(),
            p50: h.p50(),
            p99: h.p99(),
            p999: h.p999(),
        }
    }
}

/// Conversion form of [`TailLatency::from_hist`], so engine-trait consumers
/// (`CycleEngine::latency_hist` returns a [`LatencyHist`]) distil with
/// `.into()` / `TailLatency::from`.
impl From<&LatencyHist> for TailLatency {
    fn from(h: &LatencyHist) -> Self {
        TailLatency::from_hist(h)
    }
}

/// Eq. 8/9 closed-form *floor* for a packet crossing `crossings` die
/// boundaries: every crossing pays at least one full SerDes + deserializer
/// traversal (76 cycles), regardless of congestion. Measured per-packet
/// latencies must sit at or above this line; how far above is queueing.
pub fn crossing_floor_cycles(crossings: u32) -> u64 {
    crossings as u64 * emio_single_packet_cycles()
}

/// Measured-tail vs analytic-floor ratio (>= 1.0 when the cycle engine and
/// Eq. 8 agree; the excess over 1.0 is mesh + merge queueing the closed
/// form does not model). Returns the p99 ratio; 0-crossing distributions
/// compare against a 1-cycle floor (pure on-chip ejection).
pub fn tail_vs_floor(tail: &TailLatency, crossings: u32) -> f64 {
    tail.p99 as f64 / crossing_floor_cycles(crossings).max(1) as f64
}

/// Per-layer latency result.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerLatency {
    pub layer_idx: usize,
    pub compute_cycles: u64,
    pub emio_cycles: u64,
}

/// Eq. 9: total inference latency over all layers and boundary edges.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyReport {
    pub per_layer: Vec<LayerLatency>,
    pub compute_cycles: u64,
    pub emio_cycles: u64,
    pub total_cycles: u64,
    pub seconds: f64,
}

/// Evaluate the latency model for a workload vector.
pub fn latency(works: &[LayerWork], cfg: &ArchConfig) -> LatencyReport {
    let mut per_layer = Vec::with_capacity(works.len());
    let mut compute_total = 0u64;
    let mut emio_total = 0u64;
    for w in works {
        let cc = compute_cycles(w.ops, w.neurons, cfg.grouping);
        // Each die crossing on the egress edge pays one EMIO traversal;
        // N_c is capped by both the layer span and the pad ports (Eq. 8).
        // The edge codec may add per-crossing encode/decode cycles on top
        // (0 for dense/rate/top-k-delta; a full `ticks` window for TTFS —
        // see `codec::BoundaryCodec::latency_overhead_cycles`).
        let nc = w.cores.min(cfg.emio_pad_ports()).max(1);
        let per_crossing =
            emio_cycles(w.local_packets, nc) + w.egress.codec().latency_overhead_cycles(cfg.ticks);
        let ec = per_crossing * w.die_crossings as u64;
        compute_total += cc;
        emio_total += ec;
        per_layer.push(LayerLatency {
            layer_idx: w.layer_idx,
            compute_cycles: cc,
            emio_cycles: ec,
        });
    }
    let total = compute_total + emio_total;
    LatencyReport {
        per_layer,
        compute_cycles: compute_total,
        emio_cycles: emio_total,
        total_cycles: total,
        seconds: total as f64 * cfg.cycle_s(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::params::Variant;

    #[test]
    fn eq6_dense_layer() {
        // 256 neurons, fan-in 256 => 65536 MACs over 256 lanes = 256 cycles
        assert_eq!(compute_cycles(65_536, 256, 256), 256);
    }

    #[test]
    fn eq7_spiking_layer_fewer_cycles() {
        // ACCs = MACs * 0.8 at 10% activity, T=8
        let macs = 65_536u64;
        let accs = (macs as f64 * 0.8) as u64;
        assert!(compute_cycles(accs, 256, 256) < compute_cycles(macs, 256, 256));
    }

    #[test]
    fn grouping_sweep_lane_math() {
        // N=512, G=256 -> lanes 512; G=64 -> lanes 512 as well (64*8);
        // but N=100, G=256 -> lanes 256 vs G=64 -> 128: smaller grouping
        // wastes fewer idle lanes on small layers.
        assert_eq!(compute_cycles(51_200, 100, 256), 200);
        assert_eq!(compute_cycles(51_200, 100, 64), 400);
    }

    #[test]
    fn eq8_single_packet_is_76_cycles() {
        // §3.4: synthesized RTL: 76 cycles die-to-die for a single packet.
        assert_eq!(emio_single_packet_cycles(), 76);
        // the streaming formula counts the lone packet's drain cycle too:
        // floor(1/1)*38 + (1 + 38) = 77 — one cycle over the RTL figure.
        assert_eq!(emio_cycles(1, 1), 77);
    }

    #[test]
    fn eq8_pipelining_beats_serial() {
        // 1000 packets via 8 cores: serialization batches (125 x 38) plus
        // pipelined drain (1000 + 38) — far below the un-pipelined
        // 1000 x 76 bound.
        let c = emio_cycles(1000, 8);
        assert_eq!(c, (1000 / 8) * 38 + 1000 + 38);
        assert!(c < 1000 * 76);
    }

    #[test]
    fn eq8_more_boundary_cores_help() {
        assert!(emio_cycles(10_000, 8) < emio_cycles(10_000, 1));
    }

    #[test]
    fn eq8_zero_packets_zero_cycles() {
        assert_eq!(emio_cycles(0, 8), 0);
    }

    #[test]
    fn tail_latency_distils_histogram() {
        let mut h = LatencyHist::new();
        for v in [80u64, 80, 80, 80, 80, 80, 80, 80, 80, 300] {
            h.record(v);
        }
        let t = TailLatency::from_hist(&h);
        assert_eq!(TailLatency::from(&h), t, "From conversion mirrors from_hist");
        assert_eq!(t.samples, 10);
        assert_eq!(t.p50, 80);
        assert!((t.mean - 102.0).abs() < 1e-9);
        // the one outlier owns the tail; log-bin error is <= 1/32 (lower edge)
        assert!(t.p99 >= 290 && t.p99 <= 300, "p99={}", t.p99);
        assert!(t.p999 >= t.p99);
    }

    #[test]
    fn crossing_floor_composes_76_per_die() {
        assert_eq!(crossing_floor_cycles(0), 0);
        assert_eq!(crossing_floor_cycles(1), 76);
        assert_eq!(crossing_floor_cycles(7), 7 * 76);
    }

    #[test]
    fn tail_vs_floor_sane_on_measured_shape() {
        let mut h = LatencyHist::new();
        for v in [78u64, 80, 85, 90, 150] {
            h.record(v);
        }
        let t = TailLatency::from_hist(&h);
        let r = tail_vs_floor(&t, 1);
        assert!(r >= 1.0, "measured p99 must sit on or above the Eq. 8 floor");
        assert!(r < 3.0, "ratio {r} implausibly far above the floor");
        // zero-crossing traffic compares against the 1-cycle floor
        assert!(tail_vs_floor(&t, 0) >= 1.0);
    }

    #[test]
    fn eq9_totals_and_seconds() {
        use crate::analytic::workload::LayerWork;
        use crate::codec::CodecId;
        use crate::model::partition::ComputeMode;
        let works = vec![LayerWork {
            layer_idx: 0,
            name: "l0".into(),
            compute: ComputeMode::Mac,
            egress: CodecId::Dense,
            ops: 65_536,
            local_packets: 256,
            routed_packets: 512,
            avg_hops: 2.0,
            boundary_packets: 256,
            die_crossings: 1,
            cores: 1,
            neurons: 256,
            synapse_iterations: 1,
            activity: 0.0,
        }];
        let cfg = ArchConfig::baseline(Variant::Ann);
        let rep = latency(&works, &cfg);
        assert_eq!(rep.compute_cycles, 256);
        assert_eq!(rep.emio_cycles, emio_cycles(256, 1));
        assert_eq!(rep.total_cycles, rep.compute_cycles + rep.emio_cycles);
        let expect_s = rep.total_cycles as f64 / 200e6;
        assert!((rep.seconds - expect_s).abs() < 1e-15);

        // the TTFS codec pays its decode window once per crossing; the
        // other built-ins add nothing (bit-identical to pre-codec totals)
        let mut w = works[0].clone();
        w.egress = CodecId::Temporal;
        let rep_t = latency(&[w.clone()], &cfg);
        assert_eq!(rep_t.emio_cycles, rep.emio_cycles + cfg.ticks as u64);
        w.egress = CodecId::TopKDelta;
        assert_eq!(latency(&[w], &cfg).emio_cycles, rep.emio_cycles);
    }
}
