//! Energy model — §4.4, ORION-2.0 methodology scaled by the paper's
//! published ratios. Components match the Fig. 12 breakdown: PE, MEM,
//! Router, EMIO.
//!
//! Anchors (all derivable from the paper's text):
//!
//! * `E_MAC` (8-bit, 65 nm, 1.0 V, 200 MHz) is the normalization unit;
//!   we give it an absolute value of 1.0 pJ so reports carry joules.
//! * SNN accumulate = **0.06 x** a MAC (§4.4).
//! * Die-to-die movement = **10 x** a MAC per packet; = **224 x** a
//!   core-to-core hop, so one hop = 10/224 MAC (§4.4, TrueNorth/ORION).
//! * SRAM access cost scales linearly with bits read/written; weights are
//!   32-bit (ANN) vs 8-bit (SNN) per Table 2.
//! * The PE datapath is fixed at 8b x 8b (Table 2): wider operands run as
//!   `ceil(bits/8)` passes, so MAC energy scales *linearly* with precision;
//!   the spiking accumulate updates a `bits`-wide potential, also linear.
//!   This keeps the ACC/MAC ratio at 0.06 across the Fig. 13 sweep, as the
//!   paper's "values scaled accordingly" implies.

// closed-form energy counts narrow into integer picojoule/cycle tallies;
// every operand is bounded by the model shape
#![allow(clippy::cast_possible_truncation)]

use crate::arch::params::ArchConfig;
use crate::model::partition::ComputeMode;

use super::workload::LayerWork;

/// Energy lookup table (joules per event), built from an ArchConfig.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyTable {
    /// One dense MAC at the configured precision.
    pub mac_j: f64,
    /// One spiking accumulate.
    pub acc_j: f64,
    /// SRAM energy per bit accessed.
    pub sram_bit_j: f64,
    /// Router energy per packet per hop.
    pub hop_j: f64,
    /// Local-port delivery per packet.
    pub local_j: f64,
    /// EMIO die-to-die energy per packet per crossing.
    pub d2d_j: f64,
    /// ANN weight bits per op (Table 2: 32).
    pub ann_weight_bits: f64,
    /// SNN weight bits per op (Table 2 baseline: 8, tracks cfg.bits).
    pub snn_weight_bits: f64,
    /// Activation/potential bits moved per op.
    pub state_bits: f64,
}

/// Baseline MAC energy: 8-bit, 65 nm, 1.0 V (normalization anchor).
pub const E_MAC_8B_65NM: f64 = 1.0e-12;
/// §4.4: SNN inference op = 0.06x MAC.
pub const ACC_MAC_RATIO: f64 = 0.06;
/// §4.4: die-to-die packet = 10x MAC energy.
pub const D2D_MAC_RATIO: f64 = 10.0;
/// §4.4: die-to-die packet = 224x a core-to-core hop.
pub const D2D_HOP_RATIO: f64 = 224.0;
/// SRAM read/write energy per bit relative to an 8-bit MAC.
pub const SRAM_BIT_MAC_RATIO: f64 = 0.0125; // 32b read ~ 0.4x MAC

impl EnergyTable {
    pub fn for_arch(cfg: &ArchConfig) -> Self {
        // voltage scaling: dynamic energy ~ V^2 relative to the 1.0 V anchor
        let v_scale = cfg.supply_v * cfg.supply_v;
        // node scaling: linear in feature size relative to 65 nm
        let node_scale = cfg.tech_nm as f64 / 65.0;
        let unit = E_MAC_8B_65NM * v_scale * node_scale;

        let width = cfg.bits as f64 / 8.0;
        let mac_j = unit * width; // multi-pass on the 8bx8b datapath: linear
        let acc_j = unit * ACC_MAC_RATIO * width; // bits-wide potential add
        let hop_j = unit * D2D_MAC_RATIO / D2D_HOP_RATIO;
        EnergyTable {
            mac_j,
            acc_j,
            sram_bit_j: unit * SRAM_BIT_MAC_RATIO / 8.0 * 8.0 / 8.0, // per bit
            hop_j,
            local_j: hop_j * 0.5, // local port ~ half a mesh hop (no link)
            d2d_j: unit * D2D_MAC_RATIO,
            ann_weight_bits: 32.0,
            snn_weight_bits: cfg.bits as f64,
            state_bits: cfg.bits as f64,
        }
    }
}

/// Component breakdown (the Fig. 12 stacks).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct EnergyBreakdown {
    pub pe_j: f64,
    pub mem_j: f64,
    pub router_j: f64,
    pub emio_j: f64,
}

impl EnergyBreakdown {
    pub fn total_j(&self) -> f64 {
        self.pe_j + self.mem_j + self.router_j + self.emio_j
    }

    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.pe_j += other.pe_j;
        self.mem_j += other.mem_j;
        self.router_j += other.router_j;
        self.emio_j += other.emio_j;
    }
}

/// Energy of one layer's compute + traffic.
pub fn layer_energy(w: &LayerWork, table: &EnergyTable) -> EnergyBreakdown {
    // PE: op energy by compute mode.
    let pe_j = match w.compute {
        ComputeMode::Mac => w.ops as f64 * table.mac_j,
        ComputeMode::Acc => w.ops as f64 * table.acc_j,
    };

    // MEM: each op reads a weight (width by mode); weight-reload iterations
    // (fan-in beyond 256 axons) re-read the full working set. State
    // (activation or membrane potential) is read+written once per neuron
    // per effective tick.
    let weight_bits = match w.compute {
        ComputeMode::Mac => table.ann_weight_bits,
        ComputeMode::Acc => table.snn_weight_bits,
    };
    let weight_j =
        w.ops as f64 * weight_bits * table.sram_bit_j * w.synapse_iterations as f64;
    let state_j = w.neurons as f64 * 2.0 * table.state_bits * table.sram_bit_j;
    let mem_j = weight_j + state_j;

    // Router: routed packets x per-hop energy is already hop-integrated
    // (Eq. 5 multiplies local packets by average hops); local deliveries
    // pay the local-port cost.
    let router_j =
        w.routed_packets as f64 * table.hop_j + w.local_packets as f64 * table.local_j;

    // EMIO: boundary packets (already multiplied by crossings) x d2d cost,
    // scaled by the edge codec's energy hook (1.0 for every built-in codec:
    // they all fit the fixed D2D frame; see `codec::BoundaryCodec`).
    let emio_j = w.boundary_packets as f64 * table.d2d_j * w.egress.codec().d2d_energy_scale();

    EnergyBreakdown { pe_j, mem_j, router_j, emio_j }
}

/// Whole-network energy.
pub fn energy(works: &[LayerWork], cfg: &ArchConfig) -> EnergyBreakdown {
    let table = EnergyTable::for_arch(cfg);
    let mut total = EnergyBreakdown::default();
    for w in works {
        total.add(&layer_energy(w, &table));
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::params::Variant;
    use crate::codec::CodecId;

    fn work(compute: ComputeMode, ops: u64, local: u64, boundary: u64) -> LayerWork {
        LayerWork {
            layer_idx: 0,
            name: "t".into(),
            compute,
            egress: CodecId::Dense,
            ops,
            local_packets: local,
            routed_packets: local * 2,
            avg_hops: 2.0,
            boundary_packets: boundary,
            die_crossings: (boundary > 0) as usize,
            cores: 1,
            neurons: 256,
            synapse_iterations: 1,
            activity: 0.0,
        }
    }

    #[test]
    fn ratios_match_paper() {
        let cfg = ArchConfig::baseline(Variant::Hnn);
        let t = EnergyTable::for_arch(&cfg);
        assert!((t.acc_j / t.mac_j - 0.06).abs() < 1e-12); // §4.4
        assert!((t.d2d_j / t.mac_j - 10.0).abs() < 1e-9); // §4.4
        assert!((t.d2d_j / t.hop_j - 224.0).abs() < 1e-9); // §4.4
    }

    #[test]
    fn acc_cheaper_than_mac() {
        let cfg = ArchConfig::baseline(Variant::Snn);
        let t = EnergyTable::for_arch(&cfg);
        let e_mac = layer_energy(&work(ComputeMode::Mac, 1000, 0, 0), &t).pe_j;
        let e_acc = layer_energy(&work(ComputeMode::Acc, 1000, 0, 0), &t).pe_j;
        assert!((e_acc / e_mac - 0.06).abs() < 1e-9);
    }

    #[test]
    fn snn_weights_cheaper_to_read() {
        let cfg = ArchConfig::baseline(Variant::Hnn);
        let t = EnergyTable::for_arch(&cfg);
        let m_mac = layer_energy(&work(ComputeMode::Mac, 1000, 0, 0), &t).mem_j;
        let m_acc = layer_energy(&work(ComputeMode::Acc, 1000, 0, 0), &t).mem_j;
        assert!(m_acc < m_mac); // 8b vs 32b weight reads
    }

    #[test]
    fn boundary_traffic_dominates_when_present() {
        let cfg = ArchConfig::baseline(Variant::Ann);
        let t = EnergyTable::for_arch(&cfg);
        let e = layer_energy(&work(ComputeMode::Mac, 0, 256, 256), &t);
        assert!(e.emio_j > e.router_j); // 10x MAC vs (10/224)x per hop
    }

    #[test]
    fn bit_width_scaling() {
        let base = EnergyTable::for_arch(&ArchConfig::baseline(Variant::Ann));
        let wide = EnergyTable::for_arch(&ArchConfig::baseline(Variant::Ann).with_bits(32));
        assert!((wide.mac_j / base.mac_j - 4.0).abs() < 1e-9); // linear passes
        assert!((wide.acc_j / base.acc_j - 4.0).abs() < 1e-9); // linear
        // the paper's 0.06 ratio is precision-invariant
        assert!((wide.acc_j / wide.mac_j - 0.06).abs() < 1e-9);
    }

    #[test]
    fn synapse_iterations_increase_mem() {
        let cfg = ArchConfig::baseline(Variant::Ann);
        let t = EnergyTable::for_arch(&cfg);
        let mut w1 = work(ComputeMode::Mac, 1000, 0, 0);
        let mut w8 = w1.clone();
        w8.synapse_iterations = 8;
        w1.neurons = 0; // isolate weight term
        w8.neurons = 0;
        let m1 = layer_energy(&w1, &t).mem_j;
        let m8 = layer_energy(&w8, &t).mem_j;
        assert!((m8 / m1 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn builtin_codecs_share_the_d2d_frame_cost() {
        // every built-in codec fits the fixed 76-bit D2D frame, so the
        // per-packet EMIO energy is codec-invariant (the hook is identity);
        // codec savings come from *fewer packets*, not cheaper ones
        let cfg = ArchConfig::baseline(Variant::Hnn);
        let t = EnergyTable::for_arch(&cfg);
        let base = layer_energy(&work(ComputeMode::Acc, 0, 256, 256), &t).emio_j;
        for id in CodecId::ALL {
            let mut w = work(ComputeMode::Acc, 0, 256, 256);
            w.egress = id;
            assert_eq!(layer_energy(&w, &t).emio_j, base, "{id}");
        }
    }

    #[test]
    fn total_is_sum_of_components() {
        let b = EnergyBreakdown { pe_j: 1.0, mem_j: 2.0, router_j: 3.0, emio_j: 4.0 };
        assert_eq!(b.total_j(), 10.0);
    }
}
