//! Per-layer operation & packet accounting (§4.2).
//!
//! For each layer of a partitioned, mapped network this computes:
//!
//! * **ops** — MACs (dense layers) or ACCs (spiking layers; one accumulate
//!   per presynaptic spike event = MACs x activity x T);
//! * **local packets** — intra-core deliveries through the local port: the
//!   layer's egress traffic, delegated to the edge's
//!   [`crate::codec::BoundaryCodec::packets_per_edge`] (dense activations need
//!   `ceil(bits/8)` packets each per Table 3; rate-coded spikes emit
//!   `activity x T` single-bit events per neuron; see [`crate::codec`] for
//!   the temporal / top-k-delta formulas);
//! * **routed packets** — Eq. 5: local packets x AverageHops (Eq. 4);
//! * **boundary packets** — the subset of egress that crosses die(s).

// closed-form packet/cycle counts narrow deliberately; operands are
// bounded by the model shape
#![allow(clippy::cast_possible_truncation)]

use crate::arch::params::ArchConfig;
use crate::codec::CodecId;
use crate::model::layer::Network;
use crate::model::mapping::Mapping;
use crate::model::partition::{ComputeMode, Partition};
use crate::sparsity::SparsityProfile;

/// Workload of one layer (per single-input inference).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerWork {
    pub layer_idx: usize,
    pub name: String,
    pub compute: ComputeMode,
    /// Codec handle of the egress edge (resolves via [`CodecId::codec`]).
    pub egress: CodecId,
    /// MACs or ACCs depending on `compute`.
    pub ops: u64,
    /// Packets delivered through local ports (egress of this layer).
    pub local_packets: u64,
    /// Eq. 5: local x average hops.
    pub routed_packets: u64,
    /// Average hops for this layer's egress (Eq. 4).
    pub avg_hops: f64,
    /// Packets crossing die boundaries (x number of crossings).
    pub boundary_packets: u64,
    /// Die crossings on the egress edge.
    pub die_crossings: usize,
    /// Cores allocated.
    pub cores: usize,
    /// Neurons in this layer.
    pub neurons: u64,
    /// Weight-reload iterations (fan-in > 256 axons).
    pub synapse_iterations: u32,
    /// Firing activity used (spiking layers only; 0 for dense).
    pub activity: f64,
}

/// Packets one dense activation needs on the wire: 8-bit payload per
/// packet. The closed form [`crate::codec::DenseCodec`] must reproduce
/// (locked by `tests/codec_regression.rs`).
pub fn dense_packets_per_neuron(bits: u32) -> u64 {
    (bits as u64).div_ceil(8)
}

/// Spike packets one neuron emits per inference: activity x T events. The
/// closed form [`crate::codec::RateCodec`] must reproduce (locked by
/// `tests/codec_regression.rs`).
pub fn spike_packets_per_neuron(activity: f64, ticks: u32) -> f64 {
    activity * ticks as f64
}

/// Compute the full per-layer workload vector.
pub fn layer_workloads(
    net: &Network,
    mapping: &Mapping,
    part: &Partition,
    cfg: &ArchConfig,
    profile: &SparsityProfile,
) -> Vec<LayerWork> {
    let n = net.layers.len();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let layer = &net.layers[i];
        let pl = &part.layers[i];
        let place = &mapping.placements[i];
        let act = profile.activity_of(i);

        let ops = match pl.compute {
            ComputeMode::Mac => layer.macs(),
            ComputeMode::Acc => layer.accs(act, cfg.ticks),
        };

        let local_packets =
            pl.egress.codec().packets_per_edge(layer.neurons(), act, cfg.ticks, cfg.bits);

        let avg_hops = if i + 1 < n { mapping.average_hops(i, i + 1, cfg) } else { 1.0 };
        let routed_packets = (local_packets as f64 * avg_hops).round() as u64;
        let boundary_packets = local_packets * pl.die_crossings as u64;

        out.push(LayerWork {
            layer_idx: i,
            name: layer.name.clone(),
            compute: pl.compute,
            egress: pl.egress,
            ops,
            local_packets,
            routed_packets,
            avg_hops,
            boundary_packets,
            die_crossings: pl.die_crossings,
            cores: place.cores,
            neurons: layer.neurons(),
            synapse_iterations: place.synapse_iterations,
            activity: if pl.compute == ComputeMode::Acc { act } else { 0.0 },
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::params::Variant;
    use crate::model::layer::{Layer, LayerKind};
    use crate::model::mapping::map_network;
    use crate::model::partition::partition;

    fn setup(variant: Variant, n_layers: usize) -> Vec<LayerWork> {
        let cfg = ArchConfig::baseline(variant);
        let net = Network {
            name: "t".into(),
            layers: (0..n_layers)
                .map(|i| Layer::new(format!("l{i}"), LayerKind::Dense { in_f: 256, out_f: 256 }))
                .collect(),
        };
        let m = map_network(&net, &cfg);
        let p = partition(&net, &m, &cfg);
        layer_workloads(&net, &m, &p, &cfg, &SparsityProfile::uniform(n_layers, 0.1))
    }

    #[test]
    fn ann_dense_packet_math() {
        let w = setup(Variant::Ann, 4);
        // 256 neurons, 8-bit -> 1 packet each
        assert_eq!(w[0].local_packets, 256);
        assert_eq!(w[0].ops, 256 * 256); // MACs
        assert_eq!(w[0].boundary_packets, 0); // single chip
    }

    #[test]
    fn snn_spike_packet_math() {
        let w = setup(Variant::Snn, 4);
        // activity 0.1, T=8 -> 0.8 packets/neuron -> 204.8 -> 205
        assert_eq!(w[0].local_packets, 205);
        // ACCs = MACs * 0.1 * 8
        assert_eq!(w[0].ops, 52_429); // round(65536 * 0.1 * 8)
    }

    #[test]
    fn bits_scale_dense_not_spike() {
        assert_eq!(dense_packets_per_neuron(8), 1);
        assert_eq!(dense_packets_per_neuron(16), 2);
        assert_eq!(dense_packets_per_neuron(32), 4);
        assert_eq!(dense_packets_per_neuron(4), 1);
        // spikes: unchanged by precision
        assert!((spike_packets_per_neuron(0.1, 8) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn routed_ge_local() {
        for v in Variant::ALL {
            for w in setup(v, 8) {
                assert!(w.routed_packets >= w.local_packets);
                assert!(w.avg_hops >= 1.0);
            }
        }
    }

    #[test]
    fn codec_choice_orders_boundary_packets() {
        // the codec axis at matched activity: dense >= rate >= topk-delta
        // >= temporal boundary packets on the same partitioned network
        let net = Network {
            name: "t".into(),
            layers: (0..100)
                .map(|i| Layer::new(format!("l{i}"), LayerKind::Dense { in_f: 256, out_f: 256 }))
                .collect(),
        };
        let boundary = |codec: CodecId| {
            let cfg = ArchConfig::baseline(Variant::Hnn).with_boundary_codec(codec);
            let m = map_network(&net, &cfg);
            let p = partition(&net, &m, &cfg);
            layer_workloads(&net, &m, &p, &cfg, &SparsityProfile::uniform(100, 0.1))
                .iter()
                .map(|w| w.boundary_packets)
                .sum::<u64>()
        };
        let counts: Vec<u64> = CodecId::ALL.iter().map(|&c| boundary(c)).collect();
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
        assert!(
            counts.windows(2).all(|w| w[0] >= w[1]),
            "dense >= rate >= topk >= temporal violated: {counts:?}"
        );
        // dense codec on the boundary == what the ANN charges (256 packets);
        // rate stays at the legacy 205-packet lock
        assert_eq!(counts[0], 256);
        assert_eq!(counts[1], 205);
    }

    #[test]
    fn multi_chip_boundary_packets() {
        let cfg = ArchConfig::baseline(Variant::Hnn);
        let net = Network {
            name: "t".into(),
            layers: (0..100)
                .map(|i| Layer::new(format!("l{i}"), LayerKind::Dense { in_f: 256, out_f: 256 }))
                .collect(),
        };
        let m = map_network(&net, &cfg);
        let p = partition(&net, &m, &cfg);
        let w = layer_workloads(&net, &m, &p, &cfg, &SparsityProfile::uniform(100, 0.1));
        let crossing: Vec<_> = w.iter().filter(|l| l.boundary_packets > 0).collect();
        assert_eq!(crossing.len(), 1);
        // HNN: the crossing layer sends spikes -> 205 boundary packets,
        // not 256 dense ones.
        assert_eq!(crossing[0].boundary_packets, 205);
        assert_eq!(crossing[0].compute, ComputeMode::Acc);
    }
}
