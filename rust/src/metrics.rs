//! Lightweight runtime metrics: counters + log-bucketed latency histograms
//! shared by the serving path and the simulators (the ops-facing face of
//! the Layer-3 coordinator).

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic counter (lock-free).
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Log2-bucketed histogram for durations in nanoseconds: bucket k covers
/// [2^k, 2^(k+1)) ns, 0..=47 (~ up to 1.6 days). Lock-free recording.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; 48],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    pub fn record_ns(&self, ns: u64) {
        let k = (63 - ns.max(1).leading_zeros() as usize).min(47);
        self.buckets[k].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn record(&self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile (bucket upper bound), q in [0, 1].
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (k, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (k + 1);
            }
        }
        1u64 << 48
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.2}ms p50<={:.2}ms p99<={:.2}ms",
            self.count(),
            self.mean_ns() / 1e6,
            self.quantile_ns(0.50) as f64 / 1e6,
            self.quantile_ns(0.99) as f64 / 1e6,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_quantiles_bound_samples() {
        let h = Histogram::new();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record_ns(ms * 1_000_000);
        }
        assert_eq!(h.count(), 5);
        // p50 upper bound must be >= the true median (4ms) and < max*2
        let p50 = h.quantile_ns(0.5);
        assert!(p50 >= 4_000_000, "p50={p50}");
        assert!(p50 <= 16_000_000, "p50={p50}");
        let p100 = h.quantile_ns(1.0);
        assert!(p100 >= 100_000_000);
    }

    #[test]
    fn histogram_mean_exact() {
        let h = Histogram::new();
        h.record_ns(10);
        h.record_ns(30);
        assert!((h.mean_ns() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::new();
        assert_eq!(h.quantile_ns(0.99), 0);
        assert_eq!(h.mean_ns(), 0.0);
        assert!(h.summary().contains("n=0"));
    }

    #[test]
    fn concurrent_recording() {
        let h = std::sync::Arc::new(Histogram::new());
        let mut threads = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            threads.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    h.record_ns(1000 + t * 17 + i);
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }
}
